//! Packed, register-tiled f64 GEMM microkernel — the single dense
//! contraction engine behind `Mat::matmul`, `Mat::gemm_t_rows_into`,
//! `tensor::im2col::conv2d_from_patch`, and the batched Dense layers of
//! `model::Network`.
//!
//! Layout: A is packed once per call into `MR`-row strips stored
//! k-major (for each k, the strip's MR values sit adjacent), and B is
//! packed panel-by-panel into `NR`-column strips, also k-major. The
//! microkernel then streams both packed strips linearly while holding an
//! `MR×NR` accumulator block in registers: every loaded A value is used
//! NR times and every B value MR times, instead of once per load in a
//! naive ikj loop. Ragged edges are zero-padded inside the packed
//! operands — never in C, whose stores are masked to the live `mh×nw`
//! sub-block — so the kernel itself is branch-free.
//!
//! **Summation-order contract** (the repo's bit-identity rule, DESIGN.md
//! §Deterministic parallel runtime): each output element is produced by
//! exactly one accumulator that adds `a(i,k)·b(k,j)` for `k = 0…K-1` in
//! ascending order, starting from 0.0 — precisely the scalar reference
//! fold (`sum()` / repeated `+=`). No k-blocking, no pairwise
//! regrouping, no FMA contraction. One deliberate difference from some
//! scalar references: products whose coefficient is an exact zero are
//! *added* (as ±0.0) rather than skipped. For finite operands that
//! cannot change any partial sum — it can at most flip the sign of an
//! exactly-zero result, which `==` (and therefore every bit-identity
//! assertion in the suite, all of which compare via `f64::eq`) treats
//! as equal.

/// Microkernel tile height (rows of A per packed strip).
pub const MR: usize = 4;
/// Microkernel tile width (columns of B per packed strip).
pub const NR: usize = 8;
/// Column-panel width: B is packed and consumed `NC` columns at a time
/// so the packed panel (`K·NC` doubles) stays cache-resident across all
/// A strips. A multiple of `NR`.
const NC: usize = 256;

/// Read access to the left operand A (element `(i, k)` of an `M×K`
/// matrix). Implementations are thin index adapters; packing
/// monomorphizes over them, so the calls inline away.
pub trait SrcA {
    fn at(&self, i: usize, k: usize) -> f64;
}

/// Read access to the right operand B (element `(k, j)` of a `K×N`
/// matrix).
pub trait SrcB {
    fn at(&self, k: usize, j: usize) -> f64;
}

/// Row-major storage with leading dimension `ld`.
pub struct RowMajor<'a> {
    pub data: &'a [f64],
    pub ld: usize,
}

impl SrcA for RowMajor<'_> {
    #[inline(always)]
    fn at(&self, i: usize, k: usize) -> f64 {
        self.data[i * self.ld + k]
    }
}

impl SrcB for RowMajor<'_> {
    #[inline(always)]
    fn at(&self, k: usize, j: usize) -> f64 {
        self.data[k * self.ld + j]
    }
}

/// The transpose of a row-major matrix read as A: element `(i, k)` is
/// the underlying `(k, i)` — `Dᵀ` in the decode GEMM, without ever
/// materializing the transpose (packing absorbs the strided reads).
pub struct TransposedA<'a> {
    pub data: &'a [f64],
    pub ld: usize,
}

impl SrcA for TransposedA<'_> {
    #[inline(always)]
    fn at(&self, i: usize, k: usize) -> f64 {
        self.data[k * self.ld + i]
    }
}

/// B given as K independent row slices — the decode path's coded output
/// blocks, which are separate tensors rather than one flat matrix.
pub struct RowsB<'a> {
    pub rows: &'a [&'a [f64]],
}

impl SrcB for RowsB<'_> {
    #[inline(always)]
    fn at(&self, k: usize, j: usize) -> f64 {
        self.rows[k][j]
    }
}

/// B given as N independent column slices — the batched-Dense path,
/// where column j is request j's flattened activation (an implicit
/// transpose, again absorbed by packing).
pub struct ColsB<'a> {
    pub cols: &'a [&'a [f64]],
}

impl SrcB for ColsB<'_> {
    #[inline(always)]
    fn at(&self, k: usize, j: usize) -> f64 {
        self.cols[j][k]
    }
}

thread_local! {
    /// Per-thread packing scratch: GEMM calls on the serving hot path
    /// recur with the same few shapes, so the packed-operand buffers
    /// are reused instead of reallocated per call (pool threads are
    /// long-lived). Taken/put with `Cell`, so a hypothetical reentrant
    /// call degrades to a fresh allocation instead of a borrow panic.
    static PACKED_A: std::cell::Cell<Vec<f64>> = const { std::cell::Cell::new(Vec::new()) };
    static PACKED_B: std::cell::Cell<Vec<f64>> = const { std::cell::Cell::new(Vec::new()) };
}

/// Pack all of A into MR-row strips, k-major, tail rows zero-padded:
/// strip `s` holds rows `[s·MR, s·MR + MR)`; within a strip, the MR
/// values of column k sit at `[k·MR, (k+1)·MR)`. Every element of the
/// used prefix is written (padding lanes explicitly zeroed), so a
/// reused scratch buffer never leaks stale data. Returns the strip
/// count.
fn pack_a_into<A: SrcA>(a: &A, m: usize, kk: usize, packed: &mut Vec<f64>) -> usize {
    let strips = m.div_ceil(MR);
    let need = strips * kk * MR;
    if packed.len() < need {
        packed.resize(need, 0.0);
    }
    for s in 0..strips {
        let r0 = s * MR;
        let mh = MR.min(m - r0);
        let base = s * kk * MR;
        for k in 0..kk {
            let dst = base + k * MR;
            for r in 0..mh {
                packed[dst + r] = a.at(r0 + r, k);
            }
            for r in mh..MR {
                packed[dst + r] = 0.0;
            }
        }
    }
    strips
}

/// Pack the B panel covering columns `[j0, j0 + nw)` into NR-column
/// strips, k-major, tail columns zero-padded. `packed` must hold
/// `nw.div_ceil(NR) · kk · NR` values.
fn pack_b_panel<B: SrcB>(b: &B, kk: usize, j0: usize, nw: usize, packed: &mut [f64]) {
    let strips = nw.div_ceil(NR);
    for t in 0..strips {
        let c0 = j0 + t * NR;
        let cw = NR.min(j0 + nw - c0);
        let base = t * kk * NR;
        for k in 0..kk {
            let dst = base + k * NR;
            for l in 0..cw {
                packed[dst + l] = b.at(k, c0 + l);
            }
            for l in cw..NR {
                packed[dst + l] = 0.0;
            }
        }
    }
}

/// The MR×NR microkernel: fold one packed A strip against one packed B
/// strip, k ascending, one register accumulator per output element.
#[inline]
fn microkernel(a_strip: &[f64], b_strip: &[f64]) -> [[f64; NR]; MR] {
    let mut acc = [[0.0f64; NR]; MR];
    for (av, bv) in a_strip.chunks_exact(MR).zip(b_strip.chunks_exact(NR)) {
        for (accr, &a) in acc.iter_mut().zip(av) {
            for (o, &b) in accr.iter_mut().zip(bv) {
                *o += a * b;
            }
        }
    }
    acc
}

/// Contract every packed A strip against one packed B panel (columns
/// `[j0, j0 + nw)`), accumulating into C — the shared inner driver of
/// [`gemm_into`] and [`gemm_prepacked_into`].
#[allow(clippy::too_many_arguments)]
fn contract_panel(
    packed_a: &[f64],
    a_strips: usize,
    m: usize,
    kk: usize,
    panel: &[f64],
    j0: usize,
    nw: usize,
    c: &mut [f64],
    ldc: usize,
) {
    let b_strips = nw.div_ceil(NR);
    for s in 0..a_strips {
        let r0 = s * MR;
        let mh = MR.min(m - r0);
        let a_strip = &packed_a[s * kk * MR..(s + 1) * kk * MR];
        for t in 0..b_strips {
            let c0 = j0 + t * NR;
            let cw = NR.min(nw - t * NR);
            let b_strip = &panel[t * kk * NR..(t + 1) * kk * NR];
            let acc = microkernel(a_strip, b_strip);
            for (r, accr) in acc.iter().enumerate().take(mh) {
                let row0 = (r0 + r) * ldc + c0;
                for (o, &v) in c[row0..row0 + cw].iter_mut().zip(&accr[..cw]) {
                    *o += v;
                }
            }
        }
    }
}

/// `C += A·B` for a row-major C with leading dimension `ldc` (callers
/// on the bit-identity paths pass C zeroed, making this `C = A·B` with
/// the exact scalar-fold result — see the module docs). Dimensions:
/// A is `m×kk`, B is `kk×n`, C covers `m` rows of `ldc >= n` columns.
/// Packing scratch comes from per-thread buffers, so steady-state calls
/// are allocation-free.
pub fn gemm_into<A: SrcA, B: SrcB>(
    m: usize,
    n: usize,
    kk: usize,
    a: &A,
    b: &B,
    c: &mut [f64],
    ldc: usize,
) {
    if m == 0 || n == 0 || kk == 0 {
        return;
    }
    assert!(ldc >= n, "gemm_into: ldc {ldc} < n {n}");
    assert!(
        c.len() >= (m - 1) * ldc + n,
        "gemm_into: C too small for {m} rows x {ldc}"
    );
    PACKED_A.with(|ca| {
        PACKED_B.with(|cb| {
            let mut pa = ca.take();
            let mut pb = cb.take();
            let a_strips = pack_a_into(a, m, kk, &mut pa);
            let max_panel = NC.min(n).div_ceil(NR) * kk * NR;
            if pb.len() < max_panel {
                pb.resize(max_panel, 0.0);
            }
            let mut j0 = 0;
            while j0 < n {
                let nw = NC.min(n - j0);
                let b_strips = nw.div_ceil(NR);
                pack_b_panel(b, kk, j0, nw, &mut pb[..b_strips * kk * NR]);
                contract_panel(
                    &pa,
                    a_strips,
                    m,
                    kk,
                    &pb[..b_strips * kk * NR],
                    j0,
                    nw,
                    c,
                    ldc,
                );
                j0 += nw;
            }
            ca.set(pa);
            cb.set(pb);
        });
    });
}

/// A fully packed B operand (every column panel) borrowed from a
/// packing buffer, reusable across many left-hand operands: pack once,
/// contract many times — the worker-side im2col fan-out packs each
/// patch matrix once for all ℓ_B filter slabs instead of once per slab
/// pair.
pub struct PackedB<'a> {
    data: &'a [f64],
    kk: usize,
    n: usize,
}

impl PackedB<'_> {
    /// Columns of the packed operand.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The packed panel starting at column `j0` (width `nw`).
    fn panel(&self, j0: usize, nw: usize) -> &[f64] {
        let panel_stride = (NC / NR) * self.kk * NR;
        let start = (j0 / NC) * panel_stride;
        &self.data[start..start + nw.div_ceil(NR) * self.kk * NR]
    }
}

/// Pack all of B (`kk×n`) into the panel/strip layout the microkernel
/// consumes, into a caller-provided buffer (grown as needed, every used
/// element overwritten — stale contents are harmless).
pub fn pack_b_into<'a, B: SrcB>(
    b: &B,
    kk: usize,
    n: usize,
    buf: &'a mut Vec<f64>,
) -> PackedB<'a> {
    let panel_stride = (NC / NR) * kk * NR;
    let total = (n / NC) * panel_stride + (n % NC).div_ceil(NR) * kk * NR;
    if buf.len() < total {
        buf.resize(total, 0.0);
    }
    let mut j0 = 0;
    while j0 < n {
        let nw = NC.min(n - j0);
        let start = (j0 / NC) * panel_stride;
        pack_b_panel(
            b,
            kk,
            j0,
            nw,
            &mut buf[start..start + nw.div_ceil(NR) * kk * NR],
        );
        j0 += nw;
    }
    PackedB {
        data: &buf[..total],
        kk,
        n,
    }
}

/// Pack B into **this thread's** packing scratch and run `f` against
/// the packed view — the multi-contraction entry point: callers issue
/// any number of [`gemm_prepacked_into`] calls inside `f`, all sharing
/// one packing and zero steady-state allocations.
pub fn with_packed_b<B: SrcB, R>(
    b: &B,
    kk: usize,
    n: usize,
    f: impl FnOnce(&PackedB<'_>) -> R,
) -> R {
    PACKED_B.with(|cell| {
        let mut buf = cell.take();
        let r = {
            let pb = pack_b_into(b, kk, n, &mut buf);
            f(&pb)
        };
        cell.set(buf);
        r
    })
}

/// [`gemm_into`] against a pre-packed B: `C += A·B` with the identical
/// per-element fold (the packed values are the same bytes the one-shot
/// path packs), amortizing the B packing across calls.
pub fn gemm_prepacked_into<A: SrcA>(m: usize, a: &A, pb: &PackedB<'_>, c: &mut [f64], ldc: usize) {
    let (n, kk) = (pb.n, pb.kk);
    if m == 0 || n == 0 || kk == 0 {
        return;
    }
    assert!(ldc >= n, "gemm_prepacked_into: ldc {ldc} < n {n}");
    assert!(
        c.len() >= (m - 1) * ldc + n,
        "gemm_prepacked_into: C too small for {m} rows x {ldc}"
    );
    PACKED_A.with(|ca| {
        let mut pa = ca.take();
        let a_strips = pack_a_into(a, m, kk, &mut pa);
        let mut j0 = 0;
        while j0 < n {
            let nw = NC.min(n - j0);
            contract_panel(&pa, a_strips, m, kk, pb.panel(j0, nw), j0, nw, c, ldc);
            j0 += nw;
        }
        ca.set(pa);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The scalar reference fold: one accumulator per element, k
    /// ascending from 0.0 — what the kernel must reproduce bit for bit.
    fn naive(m: usize, n: usize, kk: usize, a: &dyn SrcA, b: &dyn SrcB) -> Vec<f64> {
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for k in 0..kk {
                    acc += a.at(i, k) * b.at(k, j);
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matches_scalar_fold_bitwise_across_shapes() {
        let mut rng = Rng::new(17);
        // Remainder rows/cols around MR=4 / NR=8, panel edges around
        // NC=256, and degenerate dims.
        let shapes = [
            (0usize, 0usize, 0usize),
            (0, 5, 3),
            (4, 0, 3),
            (4, 5, 0),
            (1, 1, 1),
            (3, 7, 2),
            (4, 8, 16),
            (5, 9, 7),
            (13, 17, 11),
            (33, 65, 40),
            (8, 300, 5),
            (2, 257, 1),
        ];
        for (m, n, kk) in shapes {
            let adata = rng.fill_uniform(m * kk, -1.0, 1.0);
            let bdata = rng.fill_uniform(kk * n, -1.0, 1.0);
            let a = RowMajor {
                data: &adata,
                ld: kk,
            };
            let b = RowMajor {
                data: &bdata,
                ld: n.max(1),
            };
            let mut got = vec![0.0; m * n];
            gemm_into(m, n, kk, &a, &b, &mut got, n.max(1));
            let want = naive(m, n, kk, &a, &b);
            assert_eq!(got, want, "shape {m}x{kk} · {kk}x{n}");
        }
    }

    #[test]
    fn transposed_and_column_sources_agree_with_row_major() {
        let mut rng = Rng::new(18);
        let (m, n, kk) = (6, 10, 9);
        // A as its transpose's TransposedA view.
        let at_data = rng.fill_uniform(kk * m, -1.0, 1.0); // kk x m, row-major
        let a_t = TransposedA {
            data: &at_data,
            ld: m,
        };
        // The same A materialized row-major.
        let mut a_data = vec![0.0; m * kk];
        for i in 0..m {
            for k in 0..kk {
                a_data[i * kk + k] = at_data[k * m + i];
            }
        }
        let a_rm = RowMajor {
            data: &a_data,
            ld: kk,
        };
        // B as columns and as the equivalent row-major matrix.
        let cols_data: Vec<Vec<f64>> = (0..n).map(|_| rng.fill_uniform(kk, -1.0, 1.0)).collect();
        let cols: Vec<&[f64]> = cols_data.iter().map(|c| c.as_slice()).collect();
        let b_cols = ColsB { cols: &cols };
        let mut b_data = vec![0.0; kk * n];
        for k in 0..kk {
            for j in 0..n {
                b_data[k * n + j] = cols_data[j][k];
            }
        }
        let b_rm = RowMajor {
            data: &b_data,
            ld: n,
        };
        let mut want = vec![0.0; m * n];
        gemm_into(m, n, kk, &a_rm, &b_rm, &mut want, n);
        let mut got = vec![0.0; m * n];
        gemm_into(m, n, kk, &a_t, &b_cols, &mut got, n);
        assert_eq!(got, want);
    }

    #[test]
    fn prepacked_b_matches_one_shot_packing() {
        let mut rng = Rng::new(19);
        // Shapes straddling the NC panel and NR strip boundaries.
        for (m, n, kk) in [(5usize, 9usize, 7usize), (4, 300, 11), (1, 257, 3), (13, 8, 1)] {
            let adata = rng.fill_uniform(m * kk, -1.0, 1.0);
            let bdata = rng.fill_uniform(kk * n, -1.0, 1.0);
            let a = RowMajor {
                data: &adata,
                ld: kk,
            };
            let b = RowMajor {
                data: &bdata,
                ld: n,
            };
            let mut want = vec![0.0; m * n];
            gemm_into(m, n, kk, &a, &b, &mut want, n);
            let got = with_packed_b(&b, kk, n, |pb| {
                assert_eq!(pb.n(), n);
                let mut out = vec![0.0; m * n];
                gemm_prepacked_into(m, &a, pb, &mut out, n);
                out
            });
            assert_eq!(got, want, "shape {m}x{kk} · {kk}x{n}");
        }
    }
}
