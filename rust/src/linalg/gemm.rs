//! Packed, register-tiled f64 GEMM — the single dense contraction
//! engine behind `Mat::matmul`, `Mat::gemm_t_rows_into`,
//! `tensor::im2col::conv2d_from_patch`, and the batched Dense layers of
//! `model::Network`. The MR×NR microkernel itself lives in a
//! runtime-dispatched backend family (`linalg::kernel`): portable
//! scalar, AVX2, and NEON implementations selected once per process
//! (overridable with `--kernel` / `FCDCC_KERNEL`), all bit-identical
//! on the default path. This module owns the packing orchestration and
//! monomorphizes it over the chosen backend — one `Kind` match per
//! GEMM call; inside the loops the only residual dispatch cost is the
//! SIMD wrappers' defensive feature re-check (a cached atomic load per
//! tile), which keeps the backend entry points sound as safe functions.
//!
//! Layout: A is packed once per call into `MR`-row strips stored
//! k-major (for each k, the strip's MR values sit adjacent), and B is
//! packed panel-by-panel into `NR`-column strips, also k-major. The
//! microkernel then streams both packed strips linearly while holding an
//! `MR×NR` accumulator block in registers: every loaded A value is used
//! NR times and every B value MR times, instead of once per load in a
//! naive ikj loop. Ragged edges are zero-padded inside the packed
//! operands — never in C, whose stores are masked to the live `mh×nw`
//! sub-block — so the kernel itself is branch-free.
//!
//! **Summation-order contract** (the repo's bit-identity rule, DESIGN.md
//! §Deterministic parallel runtime and §SIMD dispatch): each output
//! element is produced by exactly one accumulator (one SIMD lane, for
//! the vector backends) that adds `a(i,k)·b(k,j)` for `k = 0…K-1` in
//! ascending order, starting from 0.0 — precisely the scalar reference
//! fold (`sum()` / repeated `+=`). No k-blocking, no pairwise
//! regrouping, no FMA contraction on the default path (the opt-in
//! `fused-ma` backend is the documented exception, validated by error
//! bounds instead of `==`). One deliberate difference from some
//! scalar references: products whose coefficient is an exact zero are
//! *added* (as ±0.0) rather than skipped. For finite operands that
//! cannot change any partial sum — it can at most flip the sign of an
//! exactly-zero result, which `==` (and therefore every bit-identity
//! assertion in the suite, all of which compare via `f64::eq`) treats
//! as equal.

use super::kernel::{self, Backend, Kind};

// Tile geometry: single home in `linalg::kernel`, re-exported here for
// the existing `gemm::MR`-style call sites.
pub use super::kernel::{MR, NC, NR};

/// Read access to the left operand A (element `(i, k)` of an `M×K`
/// matrix). Implementations are thin index adapters; packing
/// monomorphizes over them, so the calls inline away.
pub trait SrcA {
    fn at(&self, i: usize, k: usize) -> f64;
}

/// Read access to the right operand B (element `(k, j)` of a `K×N`
/// matrix).
pub trait SrcB {
    fn at(&self, k: usize, j: usize) -> f64;
}

/// Row-major storage with leading dimension `ld`.
pub struct RowMajor<'a> {
    pub data: &'a [f64],
    pub ld: usize,
}

impl SrcA for RowMajor<'_> {
    #[inline(always)]
    fn at(&self, i: usize, k: usize) -> f64 {
        self.data[i * self.ld + k]
    }
}

impl SrcB for RowMajor<'_> {
    #[inline(always)]
    fn at(&self, k: usize, j: usize) -> f64 {
        self.data[k * self.ld + j]
    }
}

/// The transpose of a row-major matrix read as A: element `(i, k)` is
/// the underlying `(k, i)` — `Dᵀ` in the decode GEMM, without ever
/// materializing the transpose (packing absorbs the strided reads).
pub struct TransposedA<'a> {
    pub data: &'a [f64],
    pub ld: usize,
}

impl SrcA for TransposedA<'_> {
    #[inline(always)]
    fn at(&self, i: usize, k: usize) -> f64 {
        self.data[k * self.ld + i]
    }
}

/// B given as K independent row slices — the decode path's coded output
/// blocks, which are separate tensors rather than one flat matrix.
pub struct RowsB<'a> {
    pub rows: &'a [&'a [f64]],
}

impl SrcB for RowsB<'_> {
    #[inline(always)]
    fn at(&self, k: usize, j: usize) -> f64 {
        self.rows[k][j]
    }
}

/// B given as N independent column slices — the batched-Dense path,
/// where column j is request j's flattened activation (an implicit
/// transpose, again absorbed by packing).
pub struct ColsB<'a> {
    pub cols: &'a [&'a [f64]],
}

impl SrcB for ColsB<'_> {
    #[inline(always)]
    fn at(&self, k: usize, j: usize) -> f64 {
        self.cols[j][k]
    }
}

thread_local! {
    /// Per-thread packing scratch: GEMM calls on the serving hot path
    /// recur with the same few shapes, so the packed-operand buffers
    /// are reused instead of reallocated per call (pool threads are
    /// long-lived). Taken/put with `Cell`, so a hypothetical reentrant
    /// call degrades to a fresh allocation instead of a borrow panic.
    static PACKED_A: std::cell::Cell<Vec<f64>> = const { std::cell::Cell::new(Vec::new()) };
    static PACKED_B: std::cell::Cell<Vec<f64>> = const { std::cell::Cell::new(Vec::new()) };
}

/// Packed elements of one full `NC`-wide B panel (`NC/NR` strips of
/// `kk·NR` values each) — the stride between consecutive panels of a
/// fully packed B.
#[inline]
fn panel_stride(kk: usize) -> usize {
    (NC / NR) * kk * NR
}

/// Contract every packed A strip against one packed B panel (columns
/// `[j0, j0 + nw)`), accumulating into C — the shared inner driver of
/// [`gemm_into`] and [`gemm_prepacked_into`], monomorphized over the
/// dispatched backend.
#[allow(clippy::too_many_arguments)]
fn contract_panel<K: Backend>(
    packed_a: &[f64],
    a_strips: usize,
    m: usize,
    kk: usize,
    panel: &[f64],
    j0: usize,
    nw: usize,
    c: &mut [f64],
    ldc: usize,
) {
    let b_strips = nw.div_ceil(NR);
    for s in 0..a_strips {
        let r0 = s * MR;
        let mh = MR.min(m - r0);
        let a_strip = &packed_a[s * kk * MR..(s + 1) * kk * MR];
        for t in 0..b_strips {
            let c0 = j0 + t * NR;
            let cw = NR.min(nw - t * NR);
            let b_strip = &panel[t * kk * NR..(t + 1) * kk * NR];
            let acc = K::microkernel(a_strip, b_strip);
            for (r, accr) in acc.iter().enumerate().take(mh) {
                let row0 = (r0 + r) * ldc + c0;
                for (o, &v) in c[row0..row0 + cw].iter_mut().zip(&accr[..cw]) {
                    *o += v;
                }
            }
        }
    }
}

/// The backend-generic body of [`gemm_into`].
fn gemm_into_impl<K: Backend, A: SrcA, B: SrcB>(
    m: usize,
    n: usize,
    kk: usize,
    a: &A,
    b: &B,
    c: &mut [f64],
    ldc: usize,
) {
    if m == 0 || n == 0 || kk == 0 {
        return;
    }
    assert!(ldc >= n, "gemm_into: ldc {ldc} < n {n}");
    assert!(
        c.len() >= (m - 1) * ldc + n,
        "gemm_into: C too small for {m} rows x {ldc}"
    );
    PACKED_A.with(|ca| {
        PACKED_B.with(|cb| {
            let mut pa = ca.take();
            let mut pb = cb.take();
            let a_strips = K::pack_a(a, m, kk, &mut pa);
            let max_panel = NC.min(n).div_ceil(NR) * kk * NR;
            if pb.len() < max_panel {
                pb.resize(max_panel, 0.0);
            }
            let mut j0 = 0;
            while j0 < n {
                let nw = NC.min(n - j0);
                let b_strips = nw.div_ceil(NR);
                K::pack_b_panel(b, kk, j0, nw, &mut pb[..b_strips * kk * NR]);
                contract_panel::<K>(
                    &pa,
                    a_strips,
                    m,
                    kk,
                    &pb[..b_strips * kk * NR],
                    j0,
                    nw,
                    c,
                    ldc,
                );
                j0 += nw;
            }
            ca.set(pa);
            cb.set(pb);
        });
    });
}

/// `C += A·B` for a row-major C with leading dimension `ldc` (callers
/// on the bit-identity paths pass C zeroed, making this `C = A·B` with
/// the exact scalar-fold result — see the module docs). Dimensions:
/// A is `m×kk`, B is `kk×n`, C covers `m` rows of `ldc >= n` columns.
/// Packing scratch comes from per-thread buffers, so steady-state calls
/// are allocation-free. Runs on the **active** dispatched backend
/// (`kernel::active()`), which is bit-irrelevant on the default path.
pub fn gemm_into<A: SrcA, B: SrcB>(
    m: usize,
    n: usize,
    kk: usize,
    a: &A,
    b: &B,
    c: &mut [f64],
    ldc: usize,
) {
    gemm_into_kind(kernel::active(), m, n, kk, a, b, c, ldc);
}

/// [`gemm_into`] on an **explicit** backend — the entry point the
/// differential tests and the scalar-vs-dispatched bench records use.
#[allow(clippy::too_many_arguments)]
pub fn gemm_into_kind<A: SrcA, B: SrcB>(
    kind: Kind,
    m: usize,
    n: usize,
    kk: usize,
    a: &A,
    b: &B,
    c: &mut [f64],
    ldc: usize,
) {
    match kind {
        Kind::Scalar => gemm_into_impl::<kernel::Scalar, A, B>(m, n, kk, a, b, c, ldc),
        #[cfg(target_arch = "x86_64")]
        Kind::Avx2 => gemm_into_impl::<kernel::Avx2, A, B>(m, n, kk, a, b, c, ldc),
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => gemm_into_impl::<kernel::Neon, A, B>(m, n, kk, a, b, c, ldc),
        Kind::FusedMa => gemm_into_impl::<kernel::FusedMa, A, B>(m, n, kk, a, b, c, ldc),
        // A SIMD kind can never be *active* on a foreign architecture
        // (the dispatcher only installs available kinds); scalar keeps
        // the match total for direct callers.
        #[cfg(not(target_arch = "x86_64"))]
        Kind::Avx2 => gemm_into_impl::<kernel::Scalar, A, B>(m, n, kk, a, b, c, ldc),
        #[cfg(not(target_arch = "aarch64"))]
        Kind::Neon => gemm_into_impl::<kernel::Scalar, A, B>(m, n, kk, a, b, c, ldc),
    }
}

/// A fully packed B operand (every column panel) borrowed from a
/// packing buffer, reusable across many left-hand operands: pack once,
/// contract many times — the worker-side im2col fan-out packs each
/// patch matrix once for all ℓ_B filter slabs instead of once per slab
/// pair. Packing produces identical bytes on every backend (it is pure
/// data movement), so a prepacked operand is backend-agnostic.
pub struct PackedB<'a> {
    data: &'a [f64],
    kk: usize,
    n: usize,
}

impl PackedB<'_> {
    /// Columns of the packed operand.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The packed panel starting at column `j0` (width `nw`).
    fn panel(&self, j0: usize, nw: usize) -> &[f64] {
        let start = (j0 / NC) * panel_stride(self.kk);
        &self.data[start..start + nw.div_ceil(NR) * self.kk * NR]
    }
}

/// Pack all of B (`kk×n`) into the panel/strip layout the microkernel
/// consumes, into a caller-provided buffer (grown as needed, every used
/// element overwritten — stale contents are harmless).
pub fn pack_b_into<'a, B: SrcB>(
    b: &B,
    kk: usize,
    n: usize,
    buf: &'a mut Vec<f64>,
) -> PackedB<'a> {
    let stride = panel_stride(kk);
    let total = (n / NC) * stride + (n % NC).div_ceil(NR) * kk * NR;
    if buf.len() < total {
        buf.resize(total, 0.0);
    }
    let mut j0 = 0;
    while j0 < n {
        let nw = NC.min(n - j0);
        let start = (j0 / NC) * stride;
        // The shared scalar packing: every backend packs these exact
        // bytes (see `kernel::Backend::pack_b_panel`).
        kernel::Scalar::pack_b_panel(
            b,
            kk,
            j0,
            nw,
            &mut buf[start..start + nw.div_ceil(NR) * kk * NR],
        );
        j0 += nw;
    }
    PackedB {
        data: &buf[..total],
        kk,
        n,
    }
}

/// Pack B into **this thread's** packing scratch and run `f` against
/// the packed view — the multi-contraction entry point: callers issue
/// any number of [`gemm_prepacked_into`] calls inside `f`, all sharing
/// one packing and zero steady-state allocations.
pub fn with_packed_b<B: SrcB, R>(
    b: &B,
    kk: usize,
    n: usize,
    f: impl FnOnce(&PackedB<'_>) -> R,
) -> R {
    PACKED_B.with(|cell| {
        let mut buf = cell.take();
        let r = {
            let pb = pack_b_into(b, kk, n, &mut buf);
            f(&pb)
        };
        cell.set(buf);
        r
    })
}

/// An **owned**, fully packed A operand: every `MR`-row strip in the
/// k-major layout the microkernel streams, packed once and contracted
/// arbitrarily many times. This is the plan-resident half of the
/// prepacked hot path: coded filter slabs are packed at plan-build time
/// and shipped to workers by `Arc`, so steady-state convolutions never
/// run `pack_a` at all. Packing is pure data movement and every backend
/// packs identical bytes (see `kernel::Backend::pack_a`), so one packed
/// operand serves every dispatched backend with the bit-identical fold.
#[derive(Clone, Debug)]
pub struct PackedA {
    data: Vec<f64>,
    m: usize,
    kk: usize,
    strips: usize,
}

impl PackedA {
    /// Pack an `m×kk` left operand into the strip layout. The buffer is
    /// freshly and exactly sized — resident operands should not carry
    /// scratch slack.
    pub fn pack<A: SrcA>(a: &A, m: usize, kk: usize) -> PackedA {
        let mut data = Vec::new();
        // The shared scalar packing: identical bytes on every backend.
        let strips = kernel::Scalar::pack_a(a, m, kk, &mut data);
        PackedA { data, m, kk, strips }
    }

    /// Rows of the packed operand.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Inner (contraction) dimension of the packed operand.
    pub fn kk(&self) -> usize {
        self.kk
    }

    /// Packed elements held (zero-padding included).
    pub fn packed_len(&self) -> usize {
        self.data.len()
    }
}

/// The backend-generic body of [`gemm_prepacked_ab_into`]: both
/// operands already packed, so the call is pure panel contraction.
fn gemm_prepacked_ab_into_impl<K: Backend>(
    pa: &PackedA,
    pb: &PackedB<'_>,
    c: &mut [f64],
    ldc: usize,
) {
    let (m, n, kk) = (pa.m, pb.n, pa.kk);
    assert_eq!(
        kk, pb.kk,
        "gemm_prepacked_ab_into: inner dims differ (A kk {kk}, B kk {})",
        pb.kk
    );
    if m == 0 || n == 0 || kk == 0 {
        return;
    }
    assert!(ldc >= n, "gemm_prepacked_ab_into: ldc {ldc} < n {n}");
    assert!(
        c.len() >= (m - 1) * ldc + n,
        "gemm_prepacked_ab_into: C too small for {m} rows x {ldc}"
    );
    let mut j0 = 0;
    while j0 < n {
        let nw = NC.min(n - j0);
        contract_panel::<K>(&pa.data, pa.strips, m, kk, pb.panel(j0, nw), j0, nw, c, ldc);
        j0 += nw;
    }
}

/// `C += A·B` with **both** operands prepacked — the zero-pack GEMM the
/// steady-state worker path runs: the resident [`PackedA`] (packed once
/// at plan build) against a [`PackedB`] packed once per patch matrix.
/// Same bytes through the same panel contraction as [`gemm_into`], so
/// the result is bit-identical to the pack-per-call path. Runs on the
/// active dispatched backend.
pub fn gemm_prepacked_ab_into(pa: &PackedA, pb: &PackedB<'_>, c: &mut [f64], ldc: usize) {
    gemm_prepacked_ab_into_kind(kernel::active(), pa, pb, c, ldc);
}

/// [`gemm_prepacked_ab_into`] on an explicit backend (differential
/// tests and bench records).
pub fn gemm_prepacked_ab_into_kind(
    kind: Kind,
    pa: &PackedA,
    pb: &PackedB<'_>,
    c: &mut [f64],
    ldc: usize,
) {
    match kind {
        Kind::Scalar => gemm_prepacked_ab_into_impl::<kernel::Scalar>(pa, pb, c, ldc),
        #[cfg(target_arch = "x86_64")]
        Kind::Avx2 => gemm_prepacked_ab_into_impl::<kernel::Avx2>(pa, pb, c, ldc),
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => gemm_prepacked_ab_into_impl::<kernel::Neon>(pa, pb, c, ldc),
        Kind::FusedMa => gemm_prepacked_ab_into_impl::<kernel::FusedMa>(pa, pb, c, ldc),
        #[cfg(not(target_arch = "x86_64"))]
        Kind::Avx2 => gemm_prepacked_ab_into_impl::<kernel::Scalar>(pa, pb, c, ldc),
        #[cfg(not(target_arch = "aarch64"))]
        Kind::Neon => gemm_prepacked_ab_into_impl::<kernel::Scalar>(pa, pb, c, ldc),
    }
}

/// The backend-generic body of [`gemm_prepacked_into`].
fn gemm_prepacked_into_impl<K: Backend, A: SrcA>(
    m: usize,
    a: &A,
    pb: &PackedB<'_>,
    c: &mut [f64],
    ldc: usize,
) {
    let (n, kk) = (pb.n, pb.kk);
    if m == 0 || n == 0 || kk == 0 {
        return;
    }
    assert!(ldc >= n, "gemm_prepacked_into: ldc {ldc} < n {n}");
    assert!(
        c.len() >= (m - 1) * ldc + n,
        "gemm_prepacked_into: C too small for {m} rows x {ldc}"
    );
    PACKED_A.with(|ca| {
        let mut pa = ca.take();
        let a_strips = K::pack_a(a, m, kk, &mut pa);
        let mut j0 = 0;
        while j0 < n {
            let nw = NC.min(n - j0);
            contract_panel::<K>(&pa, a_strips, m, kk, pb.panel(j0, nw), j0, nw, c, ldc);
            j0 += nw;
        }
        ca.set(pa);
    });
}

/// [`gemm_into`] against a pre-packed B: `C += A·B` with the identical
/// per-element fold (the packed values are the same bytes the one-shot
/// path packs), amortizing the B packing across calls. Runs on the
/// active dispatched backend.
pub fn gemm_prepacked_into<A: SrcA>(m: usize, a: &A, pb: &PackedB<'_>, c: &mut [f64], ldc: usize) {
    gemm_prepacked_into_kind(kernel::active(), m, a, pb, c, ldc);
}

/// [`gemm_prepacked_into`] on an explicit backend (differential tests).
pub fn gemm_prepacked_into_kind<A: SrcA>(
    kind: Kind,
    m: usize,
    a: &A,
    pb: &PackedB<'_>,
    c: &mut [f64],
    ldc: usize,
) {
    match kind {
        Kind::Scalar => gemm_prepacked_into_impl::<kernel::Scalar, A>(m, a, pb, c, ldc),
        #[cfg(target_arch = "x86_64")]
        Kind::Avx2 => gemm_prepacked_into_impl::<kernel::Avx2, A>(m, a, pb, c, ldc),
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => gemm_prepacked_into_impl::<kernel::Neon, A>(m, a, pb, c, ldc),
        Kind::FusedMa => gemm_prepacked_into_impl::<kernel::FusedMa, A>(m, a, pb, c, ldc),
        #[cfg(not(target_arch = "x86_64"))]
        Kind::Avx2 => gemm_prepacked_into_impl::<kernel::Scalar, A>(m, a, pb, c, ldc),
        #[cfg(not(target_arch = "aarch64"))]
        Kind::Neon => gemm_prepacked_into_impl::<kernel::Scalar, A>(m, a, pb, c, ldc),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The scalar reference fold: one accumulator per element, k
    /// ascending from 0.0 — what the kernel must reproduce bit for bit.
    fn naive(m: usize, n: usize, kk: usize, a: &dyn SrcA, b: &dyn SrcB) -> Vec<f64> {
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for k in 0..kk {
                    acc += a.at(i, k) * b.at(k, j);
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    // Remainder rows/cols around MR=4 / NR=8, panel edges around
    // NC=256, and degenerate dims.
    const SHAPES: [(usize, usize, usize); 12] = [
        (0, 0, 0),
        (0, 5, 3),
        (4, 0, 3),
        (4, 5, 0),
        (1, 1, 1),
        (3, 7, 2),
        (4, 8, 16),
        (5, 9, 7),
        (13, 17, 11),
        (33, 65, 40),
        (8, 300, 5),
        (2, 257, 1),
    ];

    #[test]
    fn matches_scalar_fold_bitwise_across_shapes() {
        let mut rng = Rng::new(17);
        for (m, n, kk) in SHAPES {
            let adata = rng.fill_uniform(m * kk, -1.0, 1.0);
            let bdata = rng.fill_uniform(kk * n, -1.0, 1.0);
            let a = RowMajor {
                data: &adata,
                ld: kk,
            };
            let b = RowMajor {
                data: &bdata,
                ld: n.max(1),
            };
            let mut got = vec![0.0; m * n];
            gemm_into(m, n, kk, &a, &b, &mut got, n.max(1));
            let want = naive(m, n, kk, &a, &b);
            assert_eq!(got, want, "shape {m}x{kk} · {kk}x{n}");
        }
    }

    #[test]
    fn every_available_backend_matches_scalar_bitwise() {
        // The SIMD dispatch acceptance bar at the kernel level: every
        // runnable default-path backend reproduces the scalar fold
        // exactly, over remainder and degenerate shapes.
        let mut rng = Rng::new(20);
        for (m, n, kk) in SHAPES {
            let adata = rng.fill_uniform(m * kk, -1.0, 1.0);
            let bdata = rng.fill_uniform(kk * n, -1.0, 1.0);
            let a = RowMajor {
                data: &adata,
                ld: kk,
            };
            let b = RowMajor {
                data: &bdata,
                ld: n.max(1),
            };
            let mut want = vec![0.0; m * n];
            gemm_into_kind(Kind::Scalar, m, n, kk, &a, &b, &mut want, n.max(1));
            for kind in kernel::available() {
                let mut got = vec![0.0; m * n];
                gemm_into_kind(kind, m, n, kk, &a, &b, &mut got, n.max(1));
                assert_eq!(got, want, "kind {kind:?}, shape {m}x{kk} · {kk}x{n}");
            }
        }
    }

    #[test]
    fn fused_ma_backend_within_relative_error() {
        // The opt-in FMA backend is validated by error bounds, not ==:
        // contracting mul+add into one rounding perturbs each partial
        // sum by at most one ulp of the product.
        let mut rng = Rng::new(21);
        let (m, n, kk) = (13, 30, 64);
        let adata = rng.fill_uniform(m * kk, -1.0, 1.0);
        let bdata = rng.fill_uniform(kk * n, -1.0, 1.0);
        let a = RowMajor {
            data: &adata,
            ld: kk,
        };
        let b = RowMajor {
            data: &bdata,
            ld: n,
        };
        let mut want = vec![0.0; m * n];
        gemm_into_kind(Kind::Scalar, m, n, kk, &a, &b, &mut want, n);
        let mut got = vec![0.0; m * n];
        gemm_into_kind(Kind::FusedMa, m, n, kk, &a, &b, &mut got, n);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() <= 1e-13 * (w.abs() + 1.0),
                "fused-ma drifted: {g} vs {w}"
            );
        }
    }

    #[test]
    fn transposed_and_column_sources_agree_with_row_major() {
        let mut rng = Rng::new(18);
        let (m, n, kk) = (6, 10, 9);
        // A as its transpose's TransposedA view.
        let at_data = rng.fill_uniform(kk * m, -1.0, 1.0); // kk x m, row-major
        let a_t = TransposedA {
            data: &at_data,
            ld: m,
        };
        // The same A materialized row-major.
        let mut a_data = vec![0.0; m * kk];
        for i in 0..m {
            for k in 0..kk {
                a_data[i * kk + k] = at_data[k * m + i];
            }
        }
        let a_rm = RowMajor {
            data: &a_data,
            ld: kk,
        };
        // B as columns and as the equivalent row-major matrix.
        let cols_data: Vec<Vec<f64>> = (0..n).map(|_| rng.fill_uniform(kk, -1.0, 1.0)).collect();
        let cols: Vec<&[f64]> = cols_data.iter().map(|c| c.as_slice()).collect();
        let b_cols = ColsB { cols: &cols };
        let mut b_data = vec![0.0; kk * n];
        for k in 0..kk {
            for j in 0..n {
                b_data[k * n + j] = cols_data[j][k];
            }
        }
        let b_rm = RowMajor {
            data: &b_data,
            ld: n,
        };
        let mut want = vec![0.0; m * n];
        gemm_into(m, n, kk, &a_rm, &b_rm, &mut want, n);
        let mut got = vec![0.0; m * n];
        gemm_into(m, n, kk, &a_t, &b_cols, &mut got, n);
        assert_eq!(got, want);
    }

    #[test]
    fn prepacked_b_matches_one_shot_packing() {
        let mut rng = Rng::new(19);
        // Shapes straddling the NC panel and NR strip boundaries.
        for (m, n, kk) in [(5usize, 9usize, 7usize), (4, 300, 11), (1, 257, 3), (13, 8, 1)] {
            let adata = rng.fill_uniform(m * kk, -1.0, 1.0);
            let bdata = rng.fill_uniform(kk * n, -1.0, 1.0);
            let a = RowMajor {
                data: &adata,
                ld: kk,
            };
            let b = RowMajor {
                data: &bdata,
                ld: n,
            };
            let mut want = vec![0.0; m * n];
            gemm_into(m, n, kk, &a, &b, &mut want, n);
            let got = with_packed_b(&b, kk, n, |pb| {
                assert_eq!(pb.n(), n);
                let mut out = vec![0.0; m * n];
                gemm_prepacked_into(m, &a, pb, &mut out, n);
                out
            });
            assert_eq!(got, want, "shape {m}x{kk} · {kk}x{n}");
            // And per explicit backend: the prepacked bytes are
            // backend-agnostic, the fold stays bit-identical.
            for kind in kernel::available() {
                let got = with_packed_b(&b, kk, n, |pb| {
                    let mut out = vec![0.0; m * n];
                    gemm_prepacked_into_kind(kind, m, &a, pb, &mut out, n);
                    out
                });
                assert_eq!(got, want, "kind {kind:?}, shape {m}x{kk} · {kk}x{n}");
            }
        }
    }

    #[test]
    fn fully_prepacked_ab_matches_one_shot_packing() {
        // The zero-pack entry point: a resident PackedA contracted
        // against a PackedB must reproduce gemm_into bit for bit on
        // every available backend, including panel/strip edges and
        // degenerate dims.
        let mut rng = Rng::new(22);
        for (m, n, kk) in SHAPES {
            let adata = rng.fill_uniform(m * kk, -1.0, 1.0);
            let bdata = rng.fill_uniform(kk * n, -1.0, 1.0);
            let a = RowMajor {
                data: &adata,
                ld: kk.max(1),
            };
            let b = RowMajor {
                data: &bdata,
                ld: n.max(1),
            };
            let mut want = vec![0.0; m * n];
            gemm_into(m, n, kk, &a, &b, &mut want, n.max(1));
            let pa = PackedA::pack(&a, m, kk);
            assert_eq!(pa.m(), m);
            assert_eq!(pa.kk(), kk);
            assert_eq!(pa.packed_len(), m.div_ceil(MR) * kk * MR);
            let got = with_packed_b(&b, kk, n, |pb| {
                let mut out = vec![0.0; m * n];
                gemm_prepacked_ab_into(&pa, pb, &mut out, n.max(1));
                out
            });
            assert_eq!(got, want, "shape {m}x{kk} · {kk}x{n}");
            // Reuse of the *same* resident packing across backends: the
            // packed bytes are backend-agnostic by construction.
            for kind in kernel::available() {
                let got = with_packed_b(&b, kk, n, |pb| {
                    let mut out = vec![0.0; m * n];
                    gemm_prepacked_ab_into_kind(kind, &pa, pb, &mut out, n.max(1));
                    out
                });
                assert_eq!(got, want, "kind {kind:?}, shape {m}x{kk} · {kk}x{n}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "inner dims differ")]
    fn fully_prepacked_ab_rejects_mismatched_inner_dims() {
        let adata = vec![1.0; 4 * 3];
        let bdata = vec![1.0; 5 * 2];
        let pa = PackedA::pack(
            &RowMajor {
                data: &adata,
                ld: 3,
            },
            4,
            3,
        );
        with_packed_b(
            &RowMajor {
                data: &bdata,
                ld: 2,
            },
            5,
            2,
            |pb| {
                let mut out = vec![0.0; 4 * 2];
                gemm_prepacked_ab_into(&pa, pb, &mut out, 2);
            },
        );
    }
}
