//! LU decomposition with partial pivoting: solve / invert the recovery
//! matrix E (paper eq. (43), D = E⁻¹).

use crate::linalg::Mat;
use anyhow::{bail, Result};

/// LU factorization PA = LU with partial pivoting, stored compactly.
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
    /// Sign of the permutation (for determinants).
    sign: f64,
}

impl Lu {
    /// Factor a square matrix. Fails on exact singularity.
    pub fn factor(a: &Mat) -> Result<Self> {
        if !a.is_square() {
            bail!("Lu::factor: matrix is {}x{}, not square", a.rows, a.cols);
        }
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // Pivot search.
            let mut p = k;
            let mut best = lu.get(k, k).abs();
            for r in (k + 1)..n {
                let v = lu.get(r, k).abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best == 0.0 {
                bail!("Lu::factor: singular matrix (pivot {k} is zero)");
            }
            if p != k {
                for c in 0..n {
                    let t = lu.get(k, c);
                    lu.set(k, c, lu.get(p, c));
                    lu.set(p, c, t);
                }
                piv.swap(k, p);
                sign = -sign;
            }
            let pivot = lu.get(k, k);
            for r in (k + 1)..n {
                let f = lu.get(r, k) / pivot;
                lu.set(r, k, f);
                if f != 0.0 {
                    for c in (k + 1)..n {
                        let v = lu.get(r, c) - f * lu.get(k, c);
                        lu.set(r, c, v);
                    }
                }
            }
        }
        Ok(Self { lu, piv, sign })
    }

    pub fn n(&self) -> usize {
        self.lu.rows
    }

    /// Forward + back substitution on an already-permuted RHS, in place.
    /// Inner loops run over contiguous row slices, `j` ascending — the
    /// same per-element order as the textbook scalar loops, so results
    /// are bit-identical to them.
    fn substitute(&self, x: &mut [f64]) {
        let n = self.n();
        // Forward substitution (unit lower).
        for i in 1..n {
            let row = self.lu.row(i);
            let mut s = x[i];
            for (l, xj) in row[..i].iter().zip(x.iter()) {
                s -= l * xj;
            }
            x[i] = s;
        }
        // Back substitution.
        for i in (0..n).rev() {
            let row = self.lu.row(i);
            let mut s = x[i];
            for (u, xj) in row[i + 1..].iter().zip(x[i + 1..].iter()) {
                s -= u * xj;
            }
            x[i] = s / row[i];
        }
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n, "Lu::solve: dim mismatch");
        // Apply permutation.
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        self.substitute(&mut x);
        x
    }

    /// Solve A X = B: all RHS columns stream through one reused buffer
    /// (the permutation is applied during the gather), instead of the
    /// old allocate-a-`Mat::col`-then-allocate-the-solution round trip
    /// per column — this sits on the recovery-inversion path every
    /// `InverseCache` miss pays.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.n());
        let n = self.n();
        let mut out = Mat::zeros(b.rows, b.cols);
        let mut x = vec![0.0; n];
        for c in 0..b.cols {
            for (r, xv) in x.iter_mut().enumerate() {
                *xv = b.get(self.piv[r], c);
            }
            self.substitute(&mut x);
            for (r, xv) in x.iter().enumerate() {
                out.set(r, c, *xv);
            }
        }
        out
    }

    /// Explicit inverse: solve against the identity without ever
    /// materializing it — column c's permuted RHS is the indicator of
    /// `piv[r] == c`, written straight into the reused buffer.
    pub fn inverse(&self) -> Mat {
        let n = self.n();
        let mut out = Mat::zeros(n, n);
        let mut x = vec![0.0; n];
        for c in 0..n {
            for (r, xv) in x.iter_mut().enumerate() {
                *xv = if self.piv[r] == c { 1.0 } else { 0.0 };
            }
            self.substitute(&mut x);
            for (r, xv) in x.iter().enumerate() {
                out.set(r, c, *xv);
            }
        }
        out
    }

    pub fn determinant(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.n() {
            d *= self.lu.get(i, i);
        }
        d
    }
}

/// Convenience: invert a square matrix.
pub fn invert(a: &Mat) -> Result<Mat> {
    Ok(Lu::factor(a)?.inverse())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solves_known_system() {
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 3.0]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&[3.0, 5.0]);
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn inverse_roundtrip_random() {
        let mut rng = Rng::new(42);
        for n in [1usize, 2, 3, 5, 8, 16, 32] {
            // Random matrices are a.s. well conditioned enough at this size.
            let a = Mat::random(n, n, &mut rng);
            let inv = invert(&a).unwrap();
            let prod = a.matmul(&inv);
            let id = Mat::identity(n);
            let err: f64 = prod
                .data
                .iter()
                .zip(&id.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-8, "n={n} err={err}");
        }
    }

    #[test]
    fn rejects_singular() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn rejects_nonsquare() {
        let a = Mat::zeros(2, 3);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn determinant_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let lu = Lu::factor(&a).unwrap();
        assert!((lu.determinant() + 2.0).abs() < 1e-12);
    }

    #[test]
    fn permutation_handled() {
        // Leading zero forces a pivot swap.
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&[3.0, 7.0]);
        assert_eq!(x, vec![7.0, 3.0]);
        assert!((lu.determinant() + 1.0).abs() < 1e-12);
    }
}
