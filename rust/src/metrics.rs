//! Reporting helpers: aligned markdown tables and simple summary
//! statistics — the output layer for every bench (Tables III/IV,
//! Figs. 3–7 series) and the examples.

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    /// 99th percentile. Appended after the original fields so existing
    /// bench JSON consumers (which read by name) stay bit-compatible.
    pub p99: f64,
    pub std: f64,
}

impl Stats {
    /// Like [`Stats::from`], but an empty sample (e.g. a serving run with
    /// verification or decode accounting disabled) yields all-zero stats
    /// instead of panicking.
    pub fn from_or_zero(samples: &[f64]) -> Stats {
        if samples.is_empty() {
            return Stats {
                n: 0,
                mean: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                std: 0.0,
            };
        }
        Stats::from(samples)
    }

    pub fn from(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "Stats::from on empty sample");
        let mut s: Vec<f64> = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| s[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            n,
            mean,
            min: s[0],
            max: s[n - 1],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            std: var.sqrt(),
        }
    }
}

/// Number of buckets in a [`LatencyHistogram`]: 4 sub-buckets per
/// octave × 32 octaves above the 1 µs floor (≈ 1 µs .. 4295 s).
pub const LATENCY_BUCKETS: usize = 128;

/// Smallest latency the histogram resolves; everything below lands in
/// bucket 0.
const LATENCY_FLOOR_SECS: f64 = 1e-6;

/// Sub-buckets per octave: bucket edges grow by 2^(1/4) ≈ 1.19, so any
/// reported quantile is within ±9.5% (half a bucket) of the true value.
const BUCKETS_PER_OCTAVE: f64 = 4.0;

/// Fixed-bucket log-scale latency histogram (DESIGN.md §Serving
/// front-end & overload control). Recording is O(1) with no allocation
/// after construction — safe to keep in the serving hot loop — and the
/// quantile read side reports the geometric midpoint of the covering
/// bucket, clamped to the exactly-tracked observed min/max.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: vec![0; LATENCY_BUCKETS],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(secs: f64) -> usize {
        if secs <= LATENCY_FLOOR_SECS {
            return 0;
        }
        let idx = ((secs / LATENCY_FLOOR_SECS).log2() * BUCKETS_PER_OCTAVE).floor() as usize;
        idx.min(LATENCY_BUCKETS - 1)
    }

    /// Upper edge of bucket `i` in seconds (floor · 2^((i+1)/4)).
    pub fn bucket_upper(i: usize) -> f64 {
        LATENCY_FLOOR_SECS * 2f64.powf((i + 1) as f64 / BUCKETS_PER_OCTAVE)
    }

    pub fn record(&mut self, secs: f64) {
        let secs = if secs.is_finite() { secs.max(0.0) } else { 0.0 };
        self.counts[Self::bucket_of(secs)] += 1;
        self.total += 1;
        self.sum += secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// The q-quantile (0 < q ≤ 1) as the geometric midpoint of the
    /// bucket holding the ⌈q·total⌉-th sample, clamped to the observed
    /// [min, max]. 0.0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // The overflow bucket is unbounded above: report the
                // exactly-tracked max instead of a fictitious midpoint.
                if i == LATENCY_BUCKETS - 1 {
                    return self.max;
                }
                let mid = LATENCY_FLOOR_SECS * 2f64.powf((i as f64 + 0.5) / BUCKETS_PER_OCTAVE);
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }
}

/// Hit/miss counters of a cache — the recovery-inverse cache in the
/// decode hot path surfaces these through `ServeStats`. `misses` equals
/// the number of recomputations (recovery-matrix inversions) performed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0 when never used).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Encode-pass accounting of the program-compiled input encoder
/// (`coding::EncodeProgram`): `cols` coded slabs built, via `terms`
/// coefficient applications (axpy sweeps) where a dense scan would
/// have visited `dense_terms = k_A · cols` coefficient slots. The
/// nnz-proportionality acceptance observable: `terms < dense_terms`
/// under CRME's structural zeros, and `terms ≈ w · cols` (not
/// `k_A · cols`) under the weight-w sparse family.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EncodeStats {
    /// Coded input slabs built (columns applied).
    pub cols: u64,
    /// Nonzero coefficient applications actually performed.
    pub terms: u64,
    /// Coefficient slots a dense k_A-scan would have visited.
    pub dense_terms: u64,
}

impl EncodeStats {
    /// `terms / dense_terms` — 1.0 means the program saved nothing.
    pub fn nnz_frac(&self) -> f64 {
        if self.dense_terms == 0 {
            0.0
        } else {
            self.terms as f64 / self.dense_terms as f64
        }
    }
}

/// Counters of the worker-health state machine (`cluster::health`):
/// how often workers were demoted, quarantined, probed, and readmitted,
/// plus the raw bad-observation tallies feeding those transitions.
/// Surfaced through `ServeStats` and the serve summary line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthCounters {
    /// Healthy → Suspect transitions.
    pub suspects: u64,
    /// → Quarantined transitions (the serve-level `quarantine_events`).
    pub quarantines: u64,
    /// Quarantined → Probation transitions (tentative readmissions).
    pub probes: u64,
    /// Probation → Healthy transitions (a probe task succeeded).
    pub readmissions: u64,
    /// Explicit error replies observed.
    pub errors: u64,
    /// Corrupt replies observed (checksum mismatch at the master).
    pub corruptions: u64,
    /// Missed-deadline observations (no reply when a job timed out).
    pub timeouts: u64,
}

/// Counters of the transport/membership layer (`cluster::membership` +
/// `cluster::tcp`): heartbeat traffic, evictions/readmissions of remote
/// workers, reconnect attempts that succeeded, frames rejected by the
/// codec, and the current membership epoch. All-zero (epoch 0) on the
/// in-process channel transport, which has no membership protocol.
/// Surfaced through `ServeStats`, the serve summary line, and every
/// bench JSON record.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MembershipCounters {
    /// Heartbeat pings sent to live workers.
    pub heartbeats_sent: u64,
    /// Heartbeat intervals that elapsed without a pong.
    pub heartbeats_missed: u64,
    /// Live → Down transitions (missed-beat threshold or socket error).
    pub evictions: u64,
    /// Down → Live transitions (a previously-evicted worker re-dialed
    /// and was accepted back).
    pub readmissions: u64,
    /// Successful re-dials of a previously-connected peer.
    pub reconnects: u64,
    /// Frames rejected by the codec (bad checksum/magic/length/layout).
    pub frames_corrupt: u64,
    /// Membership epoch: bumped on every admit/evict/readmit; replies
    /// stamped with a stale session are recycled, never decoded.
    pub epoch: u64,
}

impl MembershipCounters {
    /// Append this counter set to a bench JSON record. The readmission
    /// field is named `membership_readmissions` because fault-sweep
    /// records already carry a health-level `readmissions` field.
    pub fn append_json(&self, obj: crate::util::json::JsonObj) -> crate::util::json::JsonObj {
        obj.field_u64("heartbeats_sent", self.heartbeats_sent)
            .field_u64("heartbeats_missed", self.heartbeats_missed)
            .field_u64("evictions", self.evictions)
            .field_u64("membership_readmissions", self.readmissions)
            .field_u64("reconnects", self.reconnects)
            .field_u64("frames_corrupt", self.frames_corrupt)
            .field_u64("membership_epoch", self.epoch)
    }
}

/// A simple aligned-markdown table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n### {}\n\n", self.title));
        }
        out.push_str(&line(&self.header));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Human formatting helpers used across benches.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

pub fn fmt_sci(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.is_infinite() {
        "inf".to_string()
    } else {
        format!("{v:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_known() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p99, 5.0);
        assert!((s.std - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_within_bucket_error() {
        let mut h = LatencyHistogram::new();
        // 100 samples spread over three decades: quantiles must land
        // within one bucket's relative width (2^(1/4) ≈ 19%) of truth.
        for i in 1..=100u32 {
            h.record(i as f64 * 1e-3); // 1ms .. 100ms
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-9);
        for (q, want) in [(0.50, 0.050), (0.90, 0.090), (0.99, 0.099)] {
            let got = h.quantile(q);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.20, "q={q}: got {got}, want {want} (rel {rel:.3})");
        }
        // p999 of 100 samples is the max sample; the clamp makes it exact.
        assert_eq!(h.p999(), 0.100);
    }

    #[test]
    fn histogram_edges_and_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.mean(), 0.0);

        let mut h = LatencyHistogram::new();
        h.record(0.0); // below the floor → bucket 0
        h.record(-1.0); // clamped to 0
        h.record(1e9); // far above the top bucket → clamped to the last
        assert_eq!(h.count(), 3);
        // Sub-floor samples land in bucket 0: reported within its width.
        assert!(h.quantile(1.0 / 3.0) <= LatencyHistogram::bucket_upper(0));
        assert_eq!(h.quantile(1.0), 1e9, "overflow bucket reports the max");
        // Bucket edges are monotone and the last covers > 1 hour.
        assert!(LatencyHistogram::bucket_upper(0) < LatencyHistogram::bucket_upper(1));
        assert!(LatencyHistogram::bucket_upper(LATENCY_BUCKETS - 1) > 3600.0);
    }

    #[test]
    fn from_or_zero_tolerates_empty() {
        let s = Stats::from_or_zero(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(Stats::from_or_zero(&[1.0, 3.0]).mean, 2.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(&["x".into(), "1".into()]);
        let out = t.render();
        assert!(out.contains("### T"));
        assert!(out.contains("| a | long_header |"));
        assert!(out.contains("| x | 1           |"));
    }

    #[test]
    fn cache_stats_rates() {
        let c = CacheStats { hits: 3, misses: 1 };
        assert_eq!(c.lookups(), 4);
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn encode_stats_nnz_frac() {
        let e = EncodeStats {
            cols: 10,
            terms: 25,
            dense_terms: 100,
        };
        assert!((e.nnz_frac() - 0.25).abs() < 1e-12);
        assert_eq!(EncodeStats::default().nnz_frac(), 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_secs(0.0025), "2.500ms");
        assert_eq!(fmt_sci(0.0), "0");
        assert_eq!(fmt_sci(1.23e-27), "1.23e-27");
    }
}
