//! Minimal TOML-subset config parser + the FCDCC deployment config.
//! Supports `[section]` headers, `key = value` with strings, integers,
//! floats, booleans and flat arrays — enough for deployment files like:
//!
//! ```toml
//! [cluster]
//! workers = 18
//! engine = "pjrt"
//!
//! [layer.conv1]
//! k_a = 8
//! k_b = 8
//! ```

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A parsed scalar/array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Flat dotted-key config: `section.key -> value`.
#[derive(Clone, Debug, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

fn parse_value(src: &str) -> Result<Value> {
    let s = src.trim();
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string: {s}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .ok_or_else(|| anyhow!("unterminated array: {s}"))?;
        let items: Result<Vec<Value>> = inner
            .split(',')
            .map(str::trim)
            .filter(|x| !x.is_empty())
            .map(parse_value)
            .collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

impl Config {
    pub fn parse(src: &str) -> Result<Config> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: bad section header", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            entries.insert(full_key, parse_value(value)?);
        }
        Ok(Config { entries })
    }

    pub fn load(path: &std::path::Path) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Millisecond-valued key as a [`Duration`] (deployment files carry
    /// deadlines and timeouts in integral ms, like the CLI flags).
    pub fn duration_ms_or(&self, key: &str, default_ms: u64) -> std::time::Duration {
        std::time::Duration::from_millis(
            self.get(key)
                .and_then(Value::as_usize)
                .map_or(default_ms, |v| v as u64),
        )
    }

    /// All keys under a section prefix (e.g. every `layer.*`).
    pub fn section_keys(&self, prefix: &str) -> Vec<&str> {
        let full = format!("{prefix}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&full))
            .map(String::as_str)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# deployment config
[cluster]
workers = 18
engine = "pjrt"
timeout_secs = 60.5
fast = true

[layer.conv1]
k = [8, 8]   # (k_A, k_B)
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.usize_or("cluster.workers", 0), 18);
        assert_eq!(c.str_or("cluster.engine", "x"), "pjrt");
        assert_eq!(c.f64_or("cluster.timeout_secs", 0.0), 60.5);
        assert_eq!(c.get("cluster.fast"), Some(&Value::Bool(true)));
        assert_eq!(
            c.get("layer.conv1.k"),
            Some(&Value::Array(vec![Value::Int(8), Value::Int(8)]))
        );
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("missing", 7), 7);
        assert_eq!(c.str_or("missing", "d"), "d");
        assert_eq!(
            c.duration_ms_or("missing", 250),
            std::time::Duration::from_millis(250)
        );
        let c = Config::parse("[serve]\nrequest_deadline_ms = 40\n").unwrap();
        assert_eq!(
            c.duration_ms_or("serve.request_deadline_ms", 0),
            std::time::Duration::from_millis(40)
        );
    }

    #[test]
    fn section_key_listing() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.section_keys("layer"), vec!["layer.conv1.k"]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[open").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("k = \"open").is_err());
        assert!(Config::parse("k = [1, 2").is_err());
        assert!(Config::parse("k = what").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let c = Config::parse("# only a comment\n\nx = 1 # trailing\n").unwrap();
        assert_eq!(c.usize_or("x", 0), 1);
    }
}
