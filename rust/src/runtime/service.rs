//! The PJRT service thread: owns the (non-`Send`) [`PjrtRuntime`] and
//! serves worker-task execution requests from any thread through
//! channels. Cloneable handles implement [`TaskEngine`], so simulated
//! cluster workers can use the AOT artifacts as their convolution
//! engine.

use crate::engine::TaskEngine;
use crate::fcdcc::{WorkerPayload, WorkerResult};
use crate::runtime::{manifest::artifact_name, PjrtRuntime};
use crate::tensor::{Tensor3, Tensor4};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

struct Request {
    name: String,
    xs: Vec<Tensor3>,
    /// Resident coded filter slabs, `Arc`-shared with the payload so a
    /// batched job's per-sample requests never deep-copy them.
    ks: Arc<Vec<Tensor4>>,
    reply: Sender<Result<Vec<Tensor3>>>,
}

/// Cloneable, `Send` handle to the PJRT service thread.
#[derive(Clone)]
pub struct PjrtService {
    tx: Sender<Request>,
}

/// Keeps the service thread alive; drop (after dropping all handles) to
/// shut the runtime down.
pub struct PjrtServiceHost {
    pub handle: PjrtService,
    join: Option<JoinHandle<()>>,
}

impl PjrtService {
    /// Spawn the service for an artifacts directory. Compiles the
    /// manifest eagerly so request-path latency is execution-only.
    pub fn spawn(dir: impl Into<std::path::PathBuf>) -> Result<PjrtServiceHost> {
        let dir = dir.into();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let mut rt = match PjrtRuntime::load(&dir).and_then(|mut rt| {
                    rt.compile_all()?;
                    Ok(rt)
                }) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    let out = rt.run_worker_task(&req.name, &req.xs, &req.ks);
                    let _ = req.reply.send(out);
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("PJRT service thread died during startup"))??;
        Ok(PjrtServiceHost {
            handle: PjrtService { tx },
            join: Some(join),
        })
    }

    /// Execute one worker task by artifact name.
    pub fn run_named(
        &self,
        name: &str,
        xs: Vec<Tensor3>,
        ks: Arc<Vec<Tensor4>>,
    ) -> Result<Vec<Tensor3>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request {
                name: name.to_string(),
                xs,
                ks,
                reply,
            })
            .map_err(|_| anyhow!("PJRT service is gone"))?;
        rx.recv().map_err(|_| anyhow!("PJRT service dropped request"))?
    }
}

impl Drop for PjrtServiceHost {
    fn drop(&mut self) {
        // The service thread exits when the last handle (sender) is
        // dropped; we intentionally do NOT join here — worker threads may
        // still hold cloned handles, and joining would deadlock. The
        // detached thread drains and dies once every clone is gone.
        self.join.take();
    }
}

impl TaskEngine for PjrtService {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn run(&self, payload: &WorkerPayload) -> Result<WorkerResult> {
        let x0 = payload
            .inputs
            .first()
            .ok_or_else(|| anyhow!("payload has no input slabs"))?;
        let k0 = payload
            .filters
            .first()
            .ok_or_else(|| anyhow!("payload has no filter slabs"))?;
        // Artifacts are AOT-compiled for the per-sample (ℓ_A, ℓ_B) task
        // shape; a batched payload runs the same artifact once per sample.
        let ell_a = payload.ell_a();
        let name = artifact_name(
            ell_a,
            payload.filters.len(),
            x0.c,
            x0.h,
            x0.w,
            k0.n,
            k0.kh,
            k0.kw,
            payload.conv.stride,
        );
        let mut blocks = Vec::with_capacity(payload.inputs.len() * payload.filters.len());
        for sample_slabs in payload.inputs.chunks(ell_a) {
            blocks.extend(self.run_named(
                &name,
                sample_slabs.to_vec(),
                Arc::clone(&payload.filters),
            )?);
        }
        Ok(WorkerResult {
            worker_id: payload.worker_id,
            batch: payload.batch,
            blocks,
            arena: Arc::clone(&payload.arena),
        })
    }
}
