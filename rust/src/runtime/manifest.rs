//! The artifact manifest: the contract between `python/compile/aot.py`
//! (which writes it) and the Rust runtime (which reads it). Artifact
//! names are a pure function of the worker-task slab shapes, so the
//! coordinator can look up the right executable for any planned layer.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// One AOT-compiled worker-task variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// (ell_a, C, Ĥ, W_padded)
    pub x_shape: Vec<usize>,
    /// (ell_b, N/k_b, C, K_H, K_W)
    pub k_shape: Vec<usize>,
    /// (ell_a·ell_b, N/k_b, H'_pad/k_a, W')
    pub out_shape: Vec<usize>,
    pub stride: usize,
}

impl ArtifactMeta {
    pub fn x_len(&self) -> usize {
        self.x_shape.iter().product()
    }

    pub fn k_len(&self) -> usize {
        self.k_shape.iter().product()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

/// Canonical artifact key — mirrors `artifact_name` in aot.py.
#[allow(clippy::too_many_arguments)]
pub fn artifact_name(
    ell_a: usize,
    ell_b: usize,
    c: usize,
    h: usize,
    w: usize,
    n: usize,
    kh: usize,
    kw: usize,
    stride: usize,
) -> String {
    format!("wt_ea{ell_a}_eb{ell_b}_c{c}_h{h}_w{w}_n{n}_k{kh}x{kw}_s{stride}")
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&src)
    }

    pub fn parse(src: &str) -> Result<Self> {
        let j = Json::parse(src).context("manifest is not valid JSON")?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for (i, a) in arts.iter().enumerate() {
            let field = |key: &str| {
                a.get(key)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact {i}: missing string field {key:?}"))
            };
            let shape = |key: &str| {
                a.usize_array(key)
                    .ok_or_else(|| anyhow!("artifact {i}: missing shape field {key:?}"))
            };
            artifacts.push(ArtifactMeta {
                name: field("name")?,
                file: field("file")?,
                x_shape: shape("x_shape")?,
                k_shape: shape("k_shape")?,
                out_shape: shape("out_shape")?,
                stride: a
                    .get("stride")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("artifact {i}: missing stride"))?,
            });
        }
        Ok(Self { artifacts })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find the artifact matching a worker-task slab geometry.
    #[allow(clippy::too_many_arguments)]
    pub fn lookup(
        &self,
        ell_a: usize,
        ell_b: usize,
        c: usize,
        h: usize,
        w: usize,
        n: usize,
        kh: usize,
        kw: usize,
        stride: usize,
    ) -> Option<&ArtifactMeta> {
        self.by_name(&artifact_name(ell_a, ell_b, c, h, w, n, kh, kw, stride))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dtype": "f64",
      "artifacts": [
        {
          "name": "wt_ea2_eb2_c2_h5_w10_n4_k3x3_s1",
          "file": "wt_ea2_eb2_c2_h5_w10_n4_k3x3_s1.hlo.txt",
          "layer": "testlayer", "k_a": 4, "k_b": 2,
          "ell_a": 2, "ell_b": 2,
          "x_shape": [2, 2, 5, 10],
          "k_shape": [2, 4, 2, 3, 3],
          "out_shape": [4, 4, 3, 8],
          "stride": 1
        }
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.x_shape, vec![2, 2, 5, 10]);
        assert_eq!(a.x_len(), 200);
        assert_eq!(a.k_len(), 2 * 4 * 2 * 9);
    }

    #[test]
    fn name_roundtrip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(artifact_name(2, 2, 2, 5, 10, 4, 3, 3, 1), m.artifacts[0].name);
        assert!(m.lookup(2, 2, 2, 5, 10, 4, 3, 3, 1).is_some());
        assert!(m.lookup(2, 2, 2, 5, 10, 4, 3, 3, 2).is_none());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }
}
