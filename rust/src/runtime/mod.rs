//! The PJRT runtime: loads the AOT-compiled HLO-text artifacts produced
//! by `python/compile/aot.py` and executes them on the XLA CPU client —
//! the L3↔L2 bridge. Python never runs here; the artifacts directory is
//! the entire interface.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so the runtime is wrapped in
//! a dedicated [`service::PjrtService`] thread; worker threads talk to it
//! through channels. On a real deployment each worker node owns its own
//! PJRT context — a single service thread is the 1-vCPU equivalent
//! (DESIGN.md §Hardware adaptation).

pub mod manifest;
pub mod service;

pub use manifest::{ArtifactMeta, Manifest};
pub use service::PjrtService;

use crate::tensor::{Tensor3, Tensor4};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A loaded PJRT runtime: one compiled executable per artifact.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: std::path::PathBuf,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client and read the manifest; artifacts are
    /// compiled lazily on first use (compile-once, cached).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            executables: HashMap::new(),
            dir,
        })
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self
                .manifest
                .by_name(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Eagerly compile every artifact in the manifest.
    pub fn compile_all(&mut self) -> Result<()> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Execute the worker task `name` on coded slabs, returning the
    /// ℓ_A·ℓ_B coded output blocks (slabA-major, matching the Rust
    /// reference worker).
    pub fn run_worker_task(
        &mut self,
        name: &str,
        xs: &[Tensor3],
        ks: &[Tensor4],
    ) -> Result<Vec<Tensor3>> {
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        check_shapes(&meta, xs, ks)?;

        // Pack the slab lists into the stacked f64 literals the artifact
        // expects: xs -> (ell_a, C, Ĥ, Wp), ks -> (ell_b, N/k_b, C, KH, KW).
        let mut xdata = Vec::with_capacity(meta.x_len());
        for t in xs {
            xdata.extend_from_slice(&t.data);
        }
        let mut kdata = Vec::with_capacity(meta.k_len());
        for t in ks {
            kdata.extend_from_slice(&t.data);
        }
        let xdims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        let kdims: Vec<i64> = meta.k_shape.iter().map(|&d| d as i64).collect();
        let xlit = xla::Literal::vec1(&xdata)
            .reshape(&xdims)
            .map_err(|e| anyhow!("reshape x literal: {e:?}"))?;
        let klit = xla::Literal::vec1(&kdata)
            .reshape(&kdims)
            .map_err(|e| anyhow!("reshape k literal: {e:?}"))?;

        let exe = self.executable(&meta.name)?;
        let result = exe
            .execute::<xla::Literal>(&[xlit, klit])
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let data = out
            .to_vec::<f64>()
            .map_err(|e| anyhow!("reading result of {name}: {e:?}"))?;

        let [blocks, n, h, w] = meta.out_shape[..] else {
            bail!("artifact {name}: out_shape must be rank 4");
        };
        let per = n * h * w;
        if data.len() != blocks * per {
            bail!(
                "artifact {name}: expected {} output values, got {}",
                blocks * per,
                data.len()
            );
        }
        Ok((0..blocks)
            .map(|b| Tensor3::from_vec(n, h, w, data[b * per..(b + 1) * per].to_vec()))
            .collect())
    }
}

fn check_shapes(meta: &ArtifactMeta, xs: &[Tensor3], ks: &[Tensor4]) -> Result<()> {
    let [ea, c, h, w] = meta.x_shape[..] else {
        bail!("bad x_shape in manifest");
    };
    let [eb, n, c2, kh, kw] = meta.k_shape[..] else {
        bail!("bad k_shape in manifest");
    };
    if xs.len() != ea || ks.len() != eb {
        bail!(
            "slab count mismatch: artifact wants ({ea},{eb}), got ({},{})",
            xs.len(),
            ks.len()
        );
    }
    for t in xs {
        if t.shape() != (c, h, w) {
            bail!(
                "input slab shape {:?} != artifact {:?}",
                t.shape(),
                (c, h, w)
            );
        }
    }
    for t in ks {
        if t.shape() != (n, c2, kh, kw) {
            bail!(
                "filter slab shape {:?} != artifact {:?}",
                t.shape(),
                (n, c2, kh, kw)
            );
        }
    }
    Ok(())
}
