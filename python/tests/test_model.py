"""L2 worker task vs reference, plus AOT geometry helpers."""

import numpy as np
import pytest

from compile.aot import apcp_slab_height, artifact_name, worker_shapes
from compile.kernels.ref import worker_task_ref
from compile.model import worker_task

RNG = np.random.default_rng(99)


@pytest.mark.parametrize("ell_a,ell_b", [(2, 2), (1, 2), (2, 1), (1, 1)])
def test_worker_task_matches_ref(ell_a, ell_b):
    xs = RNG.standard_normal((ell_a, 3, 9, 8))
    ks = RNG.standard_normal((ell_b, 4, 3, 3, 3))
    (got,) = worker_task(np.asarray(xs), np.asarray(ks))
    want = worker_task_ref(np.asarray(xs), np.asarray(ks))
    assert got.shape == (ell_a * ell_b, 4, 7, 6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_worker_task_block_order_is_slab_a_major():
    xs = RNG.standard_normal((2, 1, 5, 5))
    ks = RNG.standard_normal((2, 1, 1, 3, 3))
    (got,) = worker_task(np.asarray(xs), np.asarray(ks))
    from compile.kernels.ref import conv2d_ref

    for a in range(2):
        for b in range(2):
            want = conv2d_ref(xs[a], ks[b])
            np.testing.assert_allclose(
                np.asarray(got[a * 2 + b]), np.asarray(want), rtol=1e-12, atol=1e-12
            )


def test_worker_task_stride():
    xs = RNG.standard_normal((2, 2, 11, 11))
    ks = RNG.standard_normal((2, 3, 2, 3, 3))
    (got,) = worker_task(np.asarray(xs), np.asarray(ks), stride=2)
    want = worker_task_ref(np.asarray(xs), np.asarray(ks), stride=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_apcp_slab_height_matches_paper_fig2():
    # Fig. 2: H(padded)=10, K_H=3, s=1, k_A=4 -> H'=8, Ĥ=4, rows=2.
    h_hat, rows = apcp_slab_height(10, 3, 1, 4)
    assert (h_hat, rows) == (4, 2)


def test_worker_shapes_testlayer():
    layer = dict(c=2, h=12, w=10, n=8, kh=3, kw=3, stride=1, pad=0)
    s = worker_shapes(layer, 4, 2)
    assert s["x_shape"] == [2, 2, 5, 10]
    assert s["k_shape"] == [2, 4, 2, 3, 3]
    assert s["out_shape"] == [4, 4, 3, 8]
    assert artifact_name(s) == "wt_ea2_eb2_c2_h5_w10_n4_k3x3_s1"


def test_worker_shapes_degenerate_k():
    layer = dict(c=2, h=12, w=10, n=8, kh=3, kw=3, stride=1, pad=0)
    s = worker_shapes(layer, 1, 2)
    assert s["ell_a"] == 1 and s["ell_b"] == 2
    assert s["x_shape"][0] == 1
    assert s["out_shape"][0] == 2
