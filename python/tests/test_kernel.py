# pytest: kernel vs ref allclose — the CORE correctness signal.
"""L1 Pallas conv kernel vs the pure-jnp oracle, including a hypothesis
sweep over shapes, strides and dtypes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv2d import conv2d_pallas, _pick_tile
from compile.kernels.ref import conv2d_ref

RNG = np.random.default_rng(1234)


def _rand(shape):
    return RNG.standard_normal(shape)


CASES = [
    # (c, h, w, n, kh, kw, stride)
    (1, 5, 5, 1, 3, 3, 1),
    (2, 12, 10, 8, 3, 3, 1),
    (1, 28, 28, 6, 5, 5, 1),
    (3, 23, 17, 4, 5, 5, 4),
    (2, 9, 9, 4, 3, 3, 2),
    (3, 13, 13, 16, 3, 3, 1),
    (4, 8, 8, 12, 1, 1, 1),
    (2, 7, 31, 3, 3, 5, 2),
]


@pytest.mark.parametrize("case", CASES, ids=str)
def test_kernel_matches_ref(case):
    c, h, w, n, kh, kw, s = case
    x = _rand((c, h, w))
    k = _rand((n, c, kh, kw))
    got = np.asarray(conv2d_pallas(x, k, stride=s))
    want = np.asarray(conv2d_ref(x, k, stride=s))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_pick_tile_divides():
    for total in range(1, 40):
        for pref in range(1, 40):
            t = _pick_tile(total, pref)
            assert total % t == 0
            assert 1 <= t <= min(pref, total)


@settings(max_examples=60, deadline=None)
@given(
    c=st.integers(1, 4),
    n=st.integers(1, 8),
    kh=st.integers(1, 5),
    kw=st.integers(1, 5),
    extra_h=st.integers(0, 12),
    extra_w=st.integers(0, 12),
    stride=st.integers(1, 3),
)
def test_kernel_matches_ref_hypothesis(c, n, kh, kw, extra_h, extra_w, stride):
    h, w = kh + extra_h, kw + extra_w
    x = _rand((c, h, w))
    k = _rand((n, c, kh, kw))
    got = np.asarray(conv2d_pallas(x, k, stride=stride))
    want = np.asarray(conv2d_ref(x, k, stride=stride))
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_kernel_dtypes(dtype):
    x = _rand((2, 10, 10)).astype(dtype)
    k = _rand((4, 2, 3, 3)).astype(dtype)
    got = np.asarray(conv2d_pallas(x, k))
    assert got.dtype == dtype
    want = np.asarray(conv2d_ref(x, k))
    tol = 1e-4 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_kernel_linearity():
    # conv is bilinear — the property FCDCC's coding relies on.
    x1, x2 = _rand((2, 8, 8)), _rand((2, 8, 8))
    k = _rand((4, 2, 3, 3))
    a, b = 2.5, -1.25
    lhs = np.asarray(conv2d_pallas(a * x1 + b * x2, k))
    rhs = a * np.asarray(conv2d_pallas(x1, k)) + b * np.asarray(conv2d_pallas(x2, k))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-11, atol=1e-11)
