# Emit HLO text (NOT .serialize()) — jax >= 0.5 protos carry 64-bit ids
# that xla_extension 0.5.1 rejects; the HLO *text* parser reassigns ids
# and round-trips cleanly (see /opt/xla-example/README.md).
"""AOT pipeline: lower the L2 worker task to HLO-text artifacts.

`python -m compile.aot --out-dir ../artifacts` produces one
`wt_*.hlo.txt` per worker-task shape variant plus `manifest.json`
describing every artifact (shapes, stride, file). The Rust runtime
(`rust/src/runtime/`) reads the manifest, compiles each artifact once on
the PJRT CPU client, and executes them from the request path. Python is
never needed again after this step.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from compile.model import lower_worker_task  # noqa: E402

# ---------------------------------------------------------------------------
# Geometry helpers (mirrors rust/src/partition/apcp.rs — keep in sync).
# ---------------------------------------------------------------------------


def apcp_slab_height(h_padded, kh, stride, k_a):
    """Adaptive slab height Ĥ (paper eq. (24)) for a pre-padded input."""
    h_out = (h_padded - kh) // stride + 1
    assert h_out >= k_a, f"cannot split H'={h_out} into k_a={k_a}"
    h_out_pad = -(-h_out // k_a) * k_a
    rows = h_out_pad // k_a
    return (rows - 1) * stride + kh, rows


def worker_shapes(layer, k_a, k_b):
    """Per-worker coded slab shapes for a ConvLayer dict + (k_A, k_B)."""
    c, h, w = layer["c"], layer["h"], layer["w"]
    n, kh, kw = layer["n"], layer["kh"], layer["kw"]
    stride, pad = layer["stride"], layer["pad"]
    hp, wp = h + 2 * pad, w + 2 * pad
    h_hat, rows = apcp_slab_height(hp, kh, stride, k_a)
    assert n % k_b == 0, f"k_b={k_b} must divide N={n}"
    ell_a = 1 if k_a == 1 else 2
    ell_b = 1 if k_b == 1 else 2
    w_out = (wp - kw) // stride + 1
    return {
        "ell_a": ell_a,
        "ell_b": ell_b,
        "x_shape": [ell_a, c, h_hat, wp],
        "k_shape": [ell_b, n // k_b, c, kh, kw],
        "out_shape": [ell_a * ell_b, n // k_b, rows, w_out],
        "stride": stride,
    }


def artifact_name(s):
    """Canonical artifact key — mirrored by rust/src/runtime/manifest.rs."""
    ea, eb = s["ell_a"], s["ell_b"]
    _, c, h, w = s["x_shape"]
    _, n, _, kh, kw = s["k_shape"]
    return f"wt_ea{ea}_eb{eb}_c{c}_h{h}_w{w}_n{n}_k{kh}x{kw}_s{s['stride']}"


# ---------------------------------------------------------------------------
# The artifact set: every worker-task variant the Rust side executes.
# ---------------------------------------------------------------------------

LAYERS = {
    # Small layer used by rust integration tests and examples/quickstart.
    "testlayer": dict(c=2, h=12, w=10, n=8, kh=3, kw=3, stride=1, pad=0),
    # LeNet-5 ConvLs (e2e example serves these distributed).
    "lenet.conv1": dict(c=1, h=32, w=32, n=6, kh=5, kw=5, stride=1, pad=0),
    "lenet.conv2": dict(c=6, h=14, w=14, n=16, kh=5, kw=5, stride=1, pad=0),
    # AlexNet conv5 at reduced channel width: exercises a deep-layer shape
    # through the PJRT path (full-width variants run via the native engine;
    # see DESIGN.md §Hardware adaptation).
    "alexnet.conv5.s4": dict(c=96, h=13, w=13, n=64, kh=3, kw=3, stride=1, pad=1),
}

# (layer, k_a, k_b) variants to AOT-compile.
VARIANTS = [
    ("testlayer", 4, 2),
    ("testlayer", 2, 4),
    ("lenet.conv1", 4, 2),
    ("lenet.conv2", 2, 2),
    ("alexnet.conv5.s4", 2, 4),
]


def to_hlo_text(lowered):
    """stablehlo -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    seen = set()
    for layer_name, k_a, k_b in VARIANTS:
        layer = LAYERS[layer_name]
        s = worker_shapes(layer, k_a, k_b)
        name = artifact_name(s)
        if name in seen:
            continue
        seen.add(name)
        ea, c, h, w = s["x_shape"][0], *s["x_shape"][1:]
        eb, n, _, kh, kw = s["k_shape"][0], *s["k_shape"][1:]
        lowered = lower_worker_task(ea, eb, c, h, w, n, kh, kw, s["stride"])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "file": fname,
                "layer": layer_name,
                "k_a": k_a,
                "k_b": k_b,
                **s,
            }
        )
        print(f"wrote {fname} ({len(text)} chars)")
    manifest = {"dtype": "f64", "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(entries)} artifacts")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    args = p.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
