# Pure-jnp correctness oracle for the kernel.
"""Reference tensor convolution (paper eq. (1)) in plain JAX.

This is the oracle the Pallas kernel (and, transitively, the whole
Rust-side distributed pipeline) is validated against. It follows the
paper's conventions exactly: cross-correlation (no kernel flip), NCHW
feature maps, OIHW filter banks, `float64` arithmetic (the paper's
10^-27 MSE claims are double-precision claims).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402


def conv2d_ref(x, k, stride=1, pad=0):
    """Convolve x (C,H,W) with filter bank k (N,C,KH,KW) -> (N,H',W').

    Stride and zero-padding follow the paper:
    H' = floor((H + 2p - KH)/s) + 1.
    """
    x = jnp.asarray(x)
    k = jnp.asarray(k)
    assert x.ndim == 3 and k.ndim == 4, (x.shape, k.shape)
    assert x.shape[0] == k.shape[1], f"channel mismatch {x.shape} vs {k.shape}"
    y = jax.lax.conv_general_dilated(
        x[None],  # NCHW with batch 1
        k,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y[0]


def worker_task_ref(xs, ks, stride=1):
    """Reference for the L2 worker task: all pairwise convolutions of the
    coded input slabs `xs` (ell_a, C, H, W) with the coded filter slabs
    `ks` (ell_b, N, C, KH, KW), slabA-major (matching the Rust worker
    loop). Returns (ell_a * ell_b, N, H', W')."""
    outs = []
    for a in range(xs.shape[0]):
        for b in range(ks.shape[0]):
            outs.append(conv2d_ref(xs[a], ks[b], stride=stride))
    return jnp.stack(outs)
