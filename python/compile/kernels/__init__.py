# L1: Pallas kernel(s) for the paper's compute hot-spot.
"""Build-time only; never imported at runtime."""

from compile.kernels.conv2d import conv2d_pallas  # noqa: F401
from compile.kernels.ref import conv2d_ref  # noqa: F401
