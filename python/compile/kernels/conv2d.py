"""L1: the tensor-convolution hot-spot as a Pallas kernel.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the convolution is
expressed as an implicit GEMM. The grid tiles the *output* over
(output-channel tiles × output-row tiles); each grid step keeps

  * one filter slab  (TN, C, KH, KW)              in VMEM,
  * the input rows feeding its TH output rows      in VMEM,
  * an accumulator   (TN, TH·OW)                   in registers/VMEM,

and performs KH·KW MXU-shaped contractions
  acc += K[:, :, i, j] (TN×C)  @  patch_{i,j} (C×(TH·OW)).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO ops (the same schedule,
executed by the interpreter). Real-TPU efficiency is *estimated* from the
tile shapes in DESIGN.md §Perf.
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402


def _pick_tile(total, preferred):
    """Largest divisor of `total` that is <= preferred (>=1)."""
    t = min(preferred, total)
    while total % t != 0:
        t -= 1
    return t


def _conv_kernel(x_ref, k_ref, o_ref, *, stride, kh, kw, th, ow, c, tn):
    """One grid step: output tile (tn, th, ow) for output-row block
    pl.program_id(1) and output-channel block pl.program_id(0)."""
    row0 = pl.program_id(1) * th * stride
    x = x_ref[...]  # (C, H, W) — full input slab resident in VMEM
    k = k_ref[...]  # (tn, C, kh, kw) — this channel tile's filters
    acc = jnp.zeros((tn, th * ow), x.dtype)
    span_h = (th - 1) * stride + 1
    span_w = (ow - 1) * stride + 1
    for i in range(kh):
        for j in range(kw):
            zero = jnp.zeros((), row0.dtype)
            patch = jax.lax.dynamic_slice(
                x, (zero, row0 + i, zero + j), (c, span_h, span_w)
            )
            patch = patch[:, ::stride, ::stride].reshape(c, th * ow)
            # MXU-shaped contraction: (tn, c) @ (c, th*ow)
            acc = acc + jnp.dot(k[:, :, i, j], patch)
    o_ref[...] = acc.reshape(tn, th, ow)


def conv2d_pallas(x, k, stride=1, tile_n=16, tile_h=8):
    """Pallas convolution of x (C,H,W) with k (N,C,KH,KW) -> (N,H',W').

    No padding (FCDCC materializes padding in APCP before encoding).
    `tile_n`/`tile_h` are *preferred* tile sizes; actual tiles are the
    largest divisors of N and H' not exceeding them, so any shape works.
    """
    x = jnp.asarray(x)
    k = jnp.asarray(k)
    c, h, w = x.shape
    n, c2, kh, kw = k.shape
    assert c == c2, f"channel mismatch: x {x.shape} vs k {k.shape}"
    assert h >= kh and w >= kw, "kernel larger than input"
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    tn = _pick_tile(n, tile_n)
    th = _pick_tile(oh, tile_h)
    grid = (n // tn, oh // th)
    kernel = functools.partial(
        _conv_kernel, stride=stride, kh=kh, kw=kw, th=th, ow=ow, c=c, tn=tn
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Input slab: streamed whole (rows reused by adjacent tiles).
            pl.BlockSpec((c, h, w), lambda pn, ph: (0, 0, 0)),
            # Filter bank: one output-channel tile per grid step.
            pl.BlockSpec((tn, c, kh, kw), lambda pn, ph: (pn, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, th, ow), lambda pn, ph: (pn, ph, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, k)
