# L2: the paper's worker-side compute graph, calling the L1 kernel.
"""The FCDCC worker task as a JAX function (paper eqs. (39)-(40)).

A worker holds ell_a coded input slabs and ell_b coded filter slabs and
computes every pairwise tensor convolution, concatenating the coded
output blocks along a leading block axis (slabA-major — the same order
as the Rust worker loop and the recovery-matrix column order).

This module is build-time only: `aot.py` lowers `worker_task` once per
(layer-shape, k_A, k_B) variant to an HLO-text artifact which the Rust
runtime executes via PJRT. Python never runs on the request path.
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from compile.kernels.conv2d import conv2d_pallas  # noqa: E402


def worker_task(xs, ks, *, stride=1):
    """All pairwise coded convolutions for one worker.

    Args:
      xs: (ell_a, C, H, W)   coded input slabs.
      ks: (ell_b, N, C, KH, KW) coded filter slabs.
      stride: convolution stride (padding was materialized by APCP).

    Returns:
      (ell_a * ell_b, N, H', W') coded output blocks, slabA-major:
      block a*ell_b + b = conv(xs[a], ks[b]).
    """
    ell_a = xs.shape[0]
    ell_b = ks.shape[0]
    blocks = []
    for a in range(ell_a):
        for b in range(ell_b):
            blocks.append(conv2d_pallas(xs[a], ks[b], stride=stride))
    return (jnp.stack(blocks),)


def lower_worker_task(ell_a, ell_b, c, h, w, n, kh, kw, stride):
    """jit-lower `worker_task` for concrete slab shapes; returns the
    jax Lowered object (HLO extraction happens in aot.py)."""
    xs = jax.ShapeDtypeStruct((ell_a, c, h, w), jnp.float64)
    ks = jax.ShapeDtypeStruct((ell_b, n, c, kh, kw), jnp.float64)
    fn = functools.partial(worker_task, stride=stride)
    return jax.jit(fn).lower(xs, ks)
