//! Quickstart: one convolutional layer through the full FCDCC stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the complete paper workflow on a small layer:
//! APCP + KCCP partitioning, CRME encoding, a 4-worker simulated cluster
//! (one straggler injected), first-δ decoding, and the MSE vs the
//! single-node reference. Uses the AOT-compiled JAX/Pallas artifact via
//! PJRT when `artifacts/` exists, falling back to the native engine.

use anyhow::Result;
use fcdcc::cluster::{Cluster, StragglerModel};
use fcdcc::coordinator::{pjrt_engine_or_native, serve_lenet, ServeConfig};
use fcdcc::engine::TaskEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::metrics::{fmt_secs, fmt_sci};
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{conv2d, Tensor3, Tensor4};
use fcdcc::util::{mse, rng::Rng};
use std::sync::Arc;
use std::time::Duration;

fn main() -> Result<()> {
    // The layer every artifact set ships: C=2, 12×10 input, 8 filters 3×3.
    let layer = ConvLayer::new("quickstart", 2, 12, 10, 8, 3, 3, 1, 0);
    let (k_a, k_b, n) = (4, 2, 4); // δ = k_A·k_B/4 = 2, tolerates γ = 2 stragglers

    // AOT JAX/Pallas artifact via PJRT if available, else native im2col.
    let engine: Arc<dyn TaskEngine> = pjrt_engine_or_native("artifacts");

    let mut rng = Rng::new(7);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);

    // 1. Plan: geometry + CRME code.
    let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n)?;
    println!(
        "plan: k_A={k_a}, k_B={k_b}, n={n}, δ={}, γ={}",
        plan.delta(),
        n - plan.delta()
    );

    // 2. Encode filters once (model initialization).
    let coded_filters = plan.encode_filters(&k);

    // 3. Run on the simulated cluster with one slow worker.
    let mut cluster = Cluster::new(n, engine);
    let straggler = StragglerModel::FixedCount {
        count: 1,
        delay: Duration::from_millis(200),
    };
    let (y, report) = cluster.run_job(&plan, &x, &coded_filters, &straggler, &mut rng)?;
    cluster.shutdown();

    // 4. Verify against the single-node reference.
    let want = conv2d(&x, &k, layer.params());
    let err = mse(&y.data, &want.data);
    println!(
        "collected from workers {:?} in {} (decode {})",
        report.used_workers,
        fmt_secs(report.collect_secs),
        fmt_secs(report.decode_secs)
    );
    println!("output {:?}, MSE vs reference = {}", y.shape(), fmt_sci(err));
    assert!(err < 1e-20, "decode error too large");

    // 5. Batched coded serving: concurrent LeNet-5 requests reaching the
    //    same conv stage are coalesced into multi-sample coded jobs, so
    //    the recovery-matrix inversion is paid once per batch (and mostly
    //    not at all, thanks to the inverse LRU cache).
    let mut cfg = ServeConfig::default_with_engine(pjrt_engine_or_native("artifacts"));
    cfg.requests = 8;
    cfg.max_in_flight = 4;
    cfg.batch_window = 4;
    cfg.straggler = StragglerModel::FixedCount {
        count: 1,
        delay: Duration::from_millis(20),
    };
    let stats = serve_lenet(cfg)?;
    println!(
        "serve: {} requests -> {} coded jobs (mean batch {:.2}), {:.1} req/s",
        stats.requests, stats.coded_jobs, stats.mean_batch, stats.throughput_rps
    );
    println!(
        "       recovery inversions {} (cache: {} hits / {} misses, {:.0}% hit rate), logit MSE {}",
        stats.inverse_cache.misses,
        stats.inverse_cache.hits,
        stats.inverse_cache.misses,
        stats.inverse_cache.hit_rate() * 100.0,
        fmt_sci(stats.mean_logit_mse)
    );
    assert!(
        stats.inverse_cache.misses < stats.requests as u64,
        "batching must amortize inversions below one per request"
    );
    println!("quickstart OK");
    Ok(())
}
