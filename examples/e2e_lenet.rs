//! End-to-end driver (DESIGN.md §End-to-end): serve batched inference
//! requests through a LeNet-5 whose convolutional layers run on the full
//! distributed FCDCC stack — APCP/KCCP partitioning, CRME encoding, a
//! simulated heterogeneous cluster with stragglers, PJRT-executed
//! AOT JAX/Pallas worker kernels, first-δ decoding — with pooling and the
//! FC head on the master, exactly like the paper's deployment model.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_lenet
//! ```
//!
//! Reports per-request latency, throughput, master-side decode overhead,
//! and output fidelity (logit MSE + classification agreement) vs the
//! single-node reference. Results are recorded in EXPERIMENTS.md.

use anyhow::Result;
use fcdcc::cluster::StragglerModel;
use fcdcc::coordinator::{pjrt_engine_or_native, serve_lenet, ServeConfig};
use fcdcc::engine::TaskEngine;
use fcdcc::metrics::fmt_sci;
use std::sync::Arc;
use std::time::Duration;

fn run(tag: &str, engine: Arc<dyn TaskEngine>, straggler: StragglerModel) -> Result<()> {
    let mut cfg = ServeConfig::default_with_engine(engine);
    cfg.requests = 24;
    cfg.straggler = straggler;
    let stats = serve_lenet(cfg)?;
    println!(
        "[{tag}] {} requests | latency mean {:.2}ms p50 {:.2}ms p95 {:.2}ms | {:.1} req/s",
        stats.requests,
        stats.latency.mean * 1e3,
        stats.latency.p50 * 1e3,
        stats.latency.p95 * 1e3,
        stats.throughput_rps,
    );
    println!(
        "[{tag}] decode mean {:.3}ms | logit MSE {} | class mismatches {}/{}",
        stats.decode.mean * 1e3,
        fmt_sci(stats.mean_logit_mse),
        stats.class_mismatches,
        stats.requests
    );
    assert_eq!(stats.class_mismatches, 0, "distributed inference diverged");
    Ok(())
}

fn main() -> Result<()> {
    println!("e2e: distributed LeNet-5 serving (2 ConvLs via FCDCC, n=4, δ=2/1)");

    // AOT JAX/Pallas artifacts through PJRT if available, else native.
    let engine: Arc<dyn TaskEngine> = pjrt_engine_or_native("artifacts");

    run("no stragglers", Arc::clone(&engine), StragglerModel::None)?;
    run(
        "1 straggler +100ms",
        Arc::clone(&engine),
        StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(100),
        },
    )?;
    run(
        "1 worker failed",
        engine,
        StragglerModel::Failures { count: 1 },
    )?;
    println!("e2e_lenet OK");
    Ok(())
}
