//! Cost-planner walkthrough (paper §IV-E, Experiment 5): evaluate the
//! per-worker cost landscape U(k_A, k_B) for the first two AlexNet
//! ConvLs at Q = 32 (the paper's Fig. 7 setting) and print the optimal
//! configuration per layer and per Q for all three CNNs (Table IV).
//!
//! ```bash
//! cargo run --release --example cost_planner
//! ```

use anyhow::Result;
use fcdcc::coordinator::print_optimizer_table;
use fcdcc::fcdcc::cost::{self, CostModel};
use fcdcc::metrics::Table;
use fcdcc::model::zoo;

fn main() -> Result<()> {
    let cm = CostModel::paper_exp5();
    let q = 32;

    // Fig. 7: the discrete feasible landscape for AlexNet conv1 & conv2.
    for layer in &zoo::alexnet()[..2] {
        let choice = cost::optimize(layer, &cm, q).expect("feasible");
        let mut t = Table::new(
            &format!(
                "U(k_A,k_B) landscape for {} at Q={q} (real-valued k_A* = {:.1})",
                layer.name, choice.k_a_star_real
            ),
            &["k_A", "k_B", "comm_up", "comm_down", "store", "U total", "optimal"],
        );
        for c in &choice.candidates {
            t.row(&[
                c.k_a.to_string(),
                c.k_b.to_string(),
                format!("{:.0}", c.comm_up),
                format!("{:.0}", c.comm_down),
                format!("{:.0}", c.store),
                format!("{:.0}", c.total()),
                if (c.k_a, c.k_b) == (choice.best.k_a, choice.best.k_b) {
                    "  <== (k_A*, k_B*)"
                } else {
                    ""
                }
                .to_string(),
            ]);
        }
        t.print();
    }

    // Table IV: optimal configurations for every architecture and Q.
    for arch in ["lenet", "alexnet", "vgg"] {
        print_optimizer_table(arch, &[16, 32, 64])?;
    }
    Ok(())
}
