//! Straggler-resilience demo (paper Experiment 4 in miniature): sweep the
//! number of injected stragglers past the tolerance γ and watch the
//! simulated makespan stay flat until the threshold, then jump by the
//! injected delay — the defining behaviour of coded computing, and the
//! contrast with the uncoded baselines which stall at the FIRST straggler.
//!
//! ```bash
//! cargo run --release --example straggler_sweep
//! ```

use anyhow::Result;
use fcdcc::baseline::{UncodedPlan, UncodedScheme};
use fcdcc::cluster::{Cluster, StragglerModel};
use fcdcc::engine::Im2colEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::metrics::Table;
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{Tensor3, Tensor4};
use fcdcc::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn main() -> Result<()> {
    // AlexNet conv5 geometry at 1/4 channel scale (1-vCPU testbed).
    let layer = ConvLayer::new("alexnet.conv5/s4", 96, 13, 13, 64, 3, 3, 1, 1);
    let (k_a, k_b, n) = (2, 8, 8); // δ = 4, γ = 4
    let delay = Duration::from_millis(120);

    let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n)?;
    let delta = plan.delta();
    println!(
        "layer {}: k_A={k_a} k_B={k_b} n={n} δ={delta} γ={} | injected delay {:?}",
        layer.name,
        n - delta,
        delay
    );

    let mut rng = Rng::new(11);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    let coded_filters = plan.encode_filters(&k);

    let mut cluster = Cluster::new(n, Arc::new(Im2colEngine));
    let mut table = Table::new(
        "Simulated makespan vs straggler count (FCDCC vs uncoded spatial)",
        &["stragglers", "FCDCC makespan (ms)", "uncoded makespan (ms)", "within γ?"],
    );

    // Uncoded baseline: spatial split over the same n workers — EVERY
    // worker's result is required, so any straggler delays the job.
    let uncoded = UncodedPlan::new(&layer, UncodedScheme::Spatial { k: n })?;
    let sub = uncoded.subtasks(&x, &k);
    let per_task_secs = {
        let t0 = std::time::Instant::now();
        let _ = sub[0].run();
        t0.elapsed().as_secs_f64()
    };

    for stragglers in 0..=n {
        let straggler = if stragglers == 0 {
            StragglerModel::None
        } else {
            StragglerModel::FixedCount {
                count: stragglers,
                delay,
            }
        };
        let (_, report) = cluster.run_job(&plan, &x, &coded_filters, &straggler, &mut rng)?;
        // Uncoded: makespan = slowest worker = compute + (delay if any straggler).
        let uncoded_makespan =
            per_task_secs + if stragglers > 0 { delay.as_secs_f64() } else { 0.0 };
        table.row(&[
            stragglers.to_string(),
            format!("{:.1}", report.sim_makespan_secs * 1e3),
            format!("{:.1}", uncoded_makespan * 1e3),
            if stragglers <= n - delta { "yes" } else { "no" }.to_string(),
        ]);
    }
    cluster.shutdown();
    table.print();
    println!("\nNote: FCDCC absorbs up to γ stragglers (makespan flat); the uncoded");
    println!("scheme pays the full delay from the very first straggler.");
    Ok(())
}
